//! Workload substrate: synthetic benchmark query generators calibrated to
//! the paper's four evaluation sets, plus the latent per-subtask quantities
//! the execution simulator consumes.
//!
//! Substitution note (DESIGN.md section 3): GPQA / MMLU-Pro / AIME24 /
//! LiveBench-Reasoning are proprietary-ish datasets evaluated with real LLM
//! endpoints in the paper. Here each benchmark is a calibrated generative
//! model over (domain, difficulty, token counts); single-model reference
//! accuracies land near Table 1's Direct/CoT rows (see `eval::calibrate`).

pub mod profiling;
pub mod trace;

use crate::config::simparams::{benchmark_params, BenchmarkParams, SimParams, DOMAINS};
use crate::dag::{Role, TaskDag};
use crate::util::rng::Rng;

/// The paper's four evaluation benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Benchmark {
    Gpqa,
    MmluPro,
    Aime24,
    LiveBench,
}

impl Benchmark {
    pub const ALL: [Benchmark; 4] =
        [Benchmark::Gpqa, Benchmark::MmluPro, Benchmark::Aime24, Benchmark::LiveBench];

    pub fn name(&self) -> &'static str {
        match self {
            Benchmark::Gpqa => "gpqa",
            Benchmark::MmluPro => "mmlu_pro",
            Benchmark::Aime24 => "aime24",
            Benchmark::LiveBench => "livebench",
        }
    }

    /// Pretty name used in table headers.
    pub fn display(&self) -> &'static str {
        match self {
            Benchmark::Gpqa => "GPQA",
            Benchmark::MmluPro => "MMLU-Pro",
            Benchmark::Aime24 => "AIME24",
            Benchmark::LiveBench => "LiveBench-Reasoning",
        }
    }

    pub fn parse(s: &str) -> Option<Benchmark> {
        match s.to_ascii_lowercase().as_str() {
            "gpqa" => Some(Benchmark::Gpqa),
            "mmlu_pro" | "mmlupro" | "mmlu-pro" => Some(Benchmark::MmluPro),
            "aime24" | "aime" => Some(Benchmark::Aime24),
            "livebench" | "livebench-reasoning" => Some(Benchmark::LiveBench),
            _ => None,
        }
    }

    pub fn params(&self) -> BenchmarkParams {
        benchmark_params(self.name()).expect("benchmark in zoo")
    }
}

/// One synthetic query: the latent ground truth the simulator knows and the
/// router must not see directly.
#[derive(Debug, Clone)]
pub struct Query {
    pub id: u64,
    pub benchmark: Benchmark,
    /// Domain index into [`DOMAINS`].
    pub domain: usize,
    /// Latent difficulty in [0, 1].
    pub difficulty: f64,
    /// Input (prompt) tokens.
    pub query_tokens: f64,
    /// Output-token multiplier of the benchmark.
    pub tok_mult: f64,
}

impl Query {
    pub fn domain_name(&self) -> &'static str {
        DOMAINS[self.domain]
    }
}

/// Generate the benchmark's evaluation set (paper-sized by default).
pub fn generate_queries(bench: Benchmark, n: usize, seed: u64) -> Vec<Query> {
    let p = bench.params();
    let mut rng = Rng::new(seed ^ 0x9d5a_b1c3_0f77_e214);
    (0..n)
        .map(|i| {
            let difficulty = rng.beta(p.beta.0, p.beta.1);
            let query_tokens = rng.lognormal(p.query_tokens.0, p.query_tokens.1);
            Query {
                id: i as u64,
                benchmark: bench,
                domain: p.domain,
                difficulty,
                query_tokens,
                tok_mult: p.tok_mult,
            }
        })
        .collect()
}

/// Paper-sized evaluation set.
pub fn paper_queries(bench: Benchmark, seed: u64) -> Vec<Query> {
    generate_queries(bench, bench.params().n_queries, seed)
}

/// Latent ground truth for one subtask of a decomposed query.
#[derive(Debug, Clone, Copy)]
pub struct SubtaskLatent {
    /// Latent difficulty `d_i = d_q * phi_i`.
    pub difficulty: f64,
    /// Criticality `w_i` (GENERATE uses `generate_crit`).
    pub criticality: f64,
    /// Output tokens the *edge* model would generate (cloud multiplies by
    /// `cloud_verbosity`).
    pub out_tokens: f64,
}

/// Sample the latent quantities for every node of a decomposition.
///
/// Deterministic given `(query, dag shape, rng seed)` — the scheduler and
/// profiler rely on replaying the same latents across counterfactuals.
pub fn sample_latents(dag: &TaskDag, query: &Query, sp: &SimParams, rng: &mut Rng) -> Vec<SubtaskLatent> {
    let depths = dag.depths().unwrap_or_else(|| vec![0; dag.len()]);
    let max_depth = depths.iter().copied().max().unwrap_or(0).max(1);
    dag.nodes
        .iter()
        .enumerate()
        .map(|(i, node)| {
            let phi = rng.uniform(sp.phi.0, sp.phi.1);
            let difficulty = (query.difficulty * phi).min(1.0);
            let pos = depths[i] as f64 / max_depth as f64;
            let criticality = if node.role == Role::Generate {
                sp.generate_crit
            } else {
                sample_criticality_at(sp, pos, rng)
            };
            let (mu, sigma) = sp.role_tokens[node.role.index()];
            let out_tokens = rng.lognormal(mu, sigma) * query.tok_mult;
            SubtaskLatent { difficulty, criticality, out_tokens }
        })
        .collect()
}

/// Sample a non-GENERATE subtask's criticality at topological position
/// `pos` in [0, 1]: sparse pivotal mixture whose pivotal probability decays
/// with depth (see `CRIT_*` in the python mirror).
pub fn sample_criticality_at(sp: &SimParams, pos: f64, rng: &mut Rng) -> f64 {
    let p = sp.crit_p * (1.0 - sp.crit_pos_decay * pos.clamp(0.0, 1.0));
    if rng.bernoulli(p) {
        sp.crit_base + (1.0 - sp.crit_base) * rng.beta(sp.crit_high_beta.0, sp.crit_high_beta.1)
    } else {
        sp.crit_base
    }
}

/// Position-agnostic criticality draw (mid-position default), used by
/// baselines whose latent decompositions have no explicit DAG depth.
pub fn sample_criticality(sp: &SimParams, rng: &mut Rng) -> f64 {
    sample_criticality_at(sp, 0.5, rng)
}

/// Latent for a *direct* (non-decomposed) execution of the whole query.
pub fn direct_latent(query: &Query, sp: &SimParams, cloud: bool, cot: bool, rng: &mut Rng) -> SubtaskLatent {
    let (mu, sigma) = sp.direct_tokens[if cloud { 1 } else { 0 }];
    let mut out_tokens = rng.lognormal(mu, sigma) * query.tok_mult;
    if cot {
        out_tokens *= sp.cot_token_mult;
    }
    SubtaskLatent { difficulty: query.difficulty, criticality: 1.0, out_tokens }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Subtask;

    #[test]
    fn benchmark_roundtrip() {
        for b in Benchmark::ALL {
            assert_eq!(Benchmark::parse(b.name()), Some(b));
        }
        assert_eq!(Benchmark::parse("GPQA"), Some(Benchmark::Gpqa));
        assert!(Benchmark::parse("unknown").is_none());
    }

    #[test]
    fn queries_deterministic_and_in_range() {
        let a = generate_queries(Benchmark::Gpqa, 50, 7);
        let b = generate_queries(Benchmark::Gpqa, 50, 7);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.difficulty, y.difficulty);
            assert!((0.0..=1.0).contains(&x.difficulty));
            assert!(x.query_tokens > 0.0);
        }
        let c = generate_queries(Benchmark::Gpqa, 50, 8);
        assert!(a.iter().zip(&c).any(|(x, y)| x.difficulty != y.difficulty));
    }

    #[test]
    fn benchmark_difficulty_ordering() {
        // AIME24 is the hardest set, MMLU-Pro the easiest (by Beta means).
        let mean = |b: Benchmark| {
            let qs = generate_queries(b, 2000, 3);
            qs.iter().map(|q| q.difficulty).sum::<f64>() / qs.len() as f64
        };
        let aime = mean(Benchmark::Aime24);
        let gpqa = mean(Benchmark::Gpqa);
        let mmlu = mean(Benchmark::MmluPro);
        assert!(aime > gpqa && gpqa > mmlu, "aime {aime} gpqa {gpqa} mmlu {mmlu}");
    }

    #[test]
    fn paper_sizes() {
        assert_eq!(paper_queries(Benchmark::Aime24, 0).len(), 30);
        assert_eq!(paper_queries(Benchmark::Gpqa, 0).len(), 195);
    }

    #[test]
    fn latents_match_dag_shape() {
        let sp = SimParams::default();
        let dag = TaskDag::new(vec![
            Subtask::new(0, Role::Explain, "r", vec![]),
            Subtask::new(1, Role::Analyze, "a", vec![0]),
            Subtask::new(2, Role::Generate, "g", vec![1]),
        ]);
        let q = generate_queries(Benchmark::Gpqa, 1, 0).pop().unwrap();
        let mut rng = Rng::new(1);
        let lat = sample_latents(&dag, &q, &sp, &mut rng);
        assert_eq!(lat.len(), 3);
        for l in &lat {
            assert!(l.difficulty <= q.difficulty + 1e-12);
            assert!((0.0..=1.0).contains(&l.criticality));
            assert!(l.out_tokens > 0.0);
        }
        // GENERATE node gets the configured criticality.
        assert_eq!(lat[2].criticality, sp.generate_crit);
    }

    #[test]
    fn direct_latent_cot_inflates_tokens() {
        let sp = SimParams::default();
        let q = generate_queries(Benchmark::Gpqa, 1, 0).pop().unwrap();
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let plain = direct_latent(&q, &sp, true, false, &mut r1);
        let cot = direct_latent(&q, &sp, true, true, &mut r2);
        assert!((cot.out_tokens / plain.out_tokens - sp.cot_token_mult).abs() < 1e-9);
        assert_eq!(plain.criticality, 1.0);
    }
}

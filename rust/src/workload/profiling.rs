//! Offline profiling dataset (App. C "Quality and Cost Estimation").
//!
//! Reproduces the paper's reuse-and-recombine procedure on the simulation
//! substrate: per query, decompose; per subtask, paired edge/cloud
//! executions give `(dq, dl, dk)`; Eq. 24 normalizes; Eq. 25 defines the
//! utility target. The python trainer (`train_router.py`) consumes the same
//! generative model — this rust implementation exists to (a) regenerate the
//! profiling set from the coordinator side (`hybridflow profile`), and
//! (b) cross-check the two mirrors statistically in tests.

use crate::budget::BudgetState;
use crate::dag::TaskDag;
use crate::embed::FeatureContext;
use crate::engine::Backend;
use crate::models::SimExecutor;
use crate::planner::{Planner, synthetic::SyntheticPlanner};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{generate_queries, sample_latents, Benchmark, Query};

/// One profiling record.
#[derive(Debug, Clone)]
pub struct ProfileRecord {
    pub features: Vec<f32>,
    pub c_used: f64,
    /// Utility target (Eq. 25).
    pub target: f64,
    /// Raw profiled quantities.
    pub dq: f64,
    pub dl: f64,
    pub dk: f64,
}

impl ProfileRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("features", Json::from_f32_slice(&self.features)),
            ("c_used", Json::Num(self.c_used)),
            ("target", Json::Num(self.target)),
            ("dq", Json::Num(self.dq)),
            ("dl", Json::Num(self.dl)),
            ("dk", Json::Num(self.dk)),
        ])
    }
}

/// Profile a set of queries: returns per-subtask records. Paired
/// edge/cloud targets come through the [`Backend`] seam, so any endpoint
/// pair (simulated or replayed) can be profiled.
pub fn profile_queries(
    queries: &[Query],
    executor: &dyn Backend,
    planner: &SyntheticPlanner,
    seed: u64,
) -> Vec<ProfileRecord> {
    let sp = executor.sp();
    let mut rng = Rng::new(seed);
    let mut records = Vec::new();

    for q in queries {
        let plan = planner.plan(q, sp.nmax, &mut rng);
        let dag: &TaskDag = &plan.dag;
        let latents = sample_latents(dag, q, sp, &mut rng);
        let ctx = FeatureContext::new(dag, q);

        // Paired executions: deterministic mean-latency form for targets
        // (profiling averages repeated measurements).
        let mut c_used = 0.0f64;
        let mut out_tokens: Vec<f64> = latents.iter().map(|l| l.out_tokens).collect();
        let order = dag.topo_order().unwrap_or_else(|| (0..dag.len()).collect());
        for &i in &order {
            let in_tok: f64 = q.query_tokens
                + dag.nodes[i].deps.iter().map(|&d| out_tokens[d]).sum::<f64>();
            let dq = executor.true_dq(q.domain, &latents, i);
            let cloud_out = latents[i].out_tokens * sp.cloud_verbosity;
            let dl = (executor.profile(true).latency_mean(in_tok, cloud_out)
                - executor.profile(false).latency_mean(in_tok, latents[i].out_tokens))
                .max(0.0);
            let dk = executor.profile(true).api_cost(in_tok, cloud_out);
            let c = BudgetState::normalized_cost(sp, dl, dk);
            let target = (dq / (c + sp.eps_utility)).clamp(0.0, 1.0);

            let feats = ctx.features(dag, i, &latents[i], sp, &mut rng);
            records.push(ProfileRecord {
                features: feats.to_vec(),
                c_used,
                target,
                dq,
                dl,
                dk,
            });

            // Budget rolls forward under a random exploration policy, as in
            // the python mirror.
            if rng.bernoulli(0.4) {
                c_used = (c_used + c).min(2.0);
            }
            out_tokens[i] = latents[i].out_tokens;
        }
    }
    records
}

/// Standard profiling mix (paper: MMLU-Pro + Math500; we use MMLU-Pro +
/// AIME24's math domain as the stand-in for Math500 coverage).
pub fn standard_profile_set(n_queries: usize, seed: u64) -> Vec<ProfileRecord> {
    let executor = SimExecutor::paper_pair();
    let planner = SyntheticPlanner::paper_main();
    let mut queries = generate_queries(Benchmark::MmluPro, n_queries / 2, seed);
    queries.extend(generate_queries(Benchmark::Aime24, n_queries - n_queries / 2, seed + 1));
    profile_queries(&queries, &executor, &planner, seed + 2)
}

/// Serialize records as JSONL.
pub fn to_jsonl(records: &[ProfileRecord]) -> String {
    let mut s = String::new();
    for r in records {
        s.push_str(&r.to_json().to_string());
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simparams::FEAT_DIM;

    #[test]
    fn records_have_expected_shape() {
        let recs = standard_profile_set(20, 0);
        assert!(recs.len() >= 20 * 2);
        for r in &recs {
            assert_eq!(r.features.len(), FEAT_DIM);
            assert!((0.0..=1.0).contains(&r.target));
            assert!(r.dl >= 0.0 && r.dk >= 0.0);
            assert!(r.c_used >= 0.0);
        }
    }

    #[test]
    fn target_distribution_matches_python_mirror() {
        // With the sparse-criticality generative model the python profiling
        // set has target mean ~0.3 with a pivotal high-utility tail. The
        // rust mirror on a similar mix must land in the same regime.
        let recs = standard_profile_set(300, 1);
        let t: Vec<f64> = recs.iter().map(|r| r.target).collect();
        let mean = t.iter().sum::<f64>() / t.len() as f64;
        let frac_one = t.iter().filter(|&&x| x >= 1.0).count() as f64 / t.len() as f64;
        assert!((0.15..=0.6).contains(&mean), "target mean {mean}");
        assert!(frac_one < 0.5, "clipped fraction {frac_one}");
        // Bimodality: a meaningful pivotal tail above 0.5.
        let high = t.iter().filter(|&&x| x > 0.5).count() as f64 / t.len() as f64;
        assert!((0.05..=0.6).contains(&high), "pivotal tail {high}");
    }

    #[test]
    fn jsonl_roundtrips() {
        let recs = standard_profile_set(5, 2);
        let text = to_jsonl(&recs[..3]);
        let lines: Vec<&str> = text.trim().lines().collect();
        assert_eq!(lines.len(), 3);
        for (line, rec) in lines.iter().zip(&recs) {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("target").and_then(Json::as_f64).unwrap(), rec.target);
            assert_eq!(j.get("features").and_then(Json::as_arr).unwrap().len(), FEAT_DIM);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = standard_profile_set(10, 3);
        let b = standard_profile_set(10, 3);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.target, y.target);
            assert_eq!(x.features, y.features);
        }
    }
}

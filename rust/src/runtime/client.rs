//! PJRT engine: loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client (`xla` crate).
//!
//! This is the single place where python-authored compute enters the rust
//! request path. Artifacts are compiled once at startup; per-request work is
//! literal packing + `execute` only.
//!
//! The `xla` wrapper types hold raw pointers (not `Send`), so
//! [`PjrtEngine`] must stay on one thread — the multithreaded coordinator
//! talks to it through [`super::service::RouterService`].
//!
//! Build gating: the `xla` crate is an external native dependency that the
//! offline build cannot fetch, so the real engine is compiled only with
//! `--features pjrt`. The default build ships a stub whose `load` fails
//! fast; every consumer (CLI `check`, serving examples, artifact tests)
//! already handles that error path and falls back to the pure-rust
//! [`crate::router::MirrorPredictor`].

use crate::embed::Features;
use std::path::Path;

/// Router batch sizes emitted by `aot.py` (smallest-fitting is chosen).
pub const ROUTER_BATCHES: [usize; 3] = [1, 8, 32];

/// Edge-LM chunk shape (matches `model.EDGE_LM_T/D`).
pub const EDGE_LM_T: usize = 32;
pub const EDGE_LM_D: usize = 64;

#[cfg(feature = "pjrt")]
pub use pjrt_impl::PjrtEngine;

#[cfg(not(feature = "pjrt"))]
pub use stub_impl::PjrtEngine;

/// Smallest compiled batch size that fits `n` rows (falls back to the
/// largest and chunks when `n` exceeds it).
fn pick_batch_size(n: usize) -> usize {
    for b in ROUTER_BATCHES {
        if n <= b {
            return b;
        }
    }
    *ROUTER_BATCHES.last().unwrap()
}

/// Stub engine for builds without the `xla` dependency: construction fails
/// fast with an actionable message, so `RouterService::start` surfaces the
/// same error a missing artifact would.
#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use super::*;
    use std::path::PathBuf;

    pub struct PjrtEngine {
        pub artifacts_dir: PathBuf,
    }

    impl PjrtEngine {
        pub fn load(artifacts_dir: &Path) -> anyhow::Result<PjrtEngine> {
            let _ = artifacts_dir;
            anyhow::bail!(
                "PJRT backend not compiled in (build with `--features pjrt` and the `xla` \
                 crate available); use the pure-rust mirror predictor instead"
            )
        }

        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        pub fn pick_batch(&self, n: usize) -> usize {
            pick_batch_size(n)
        }

        pub fn score(&self, _feats: &[Features], _c_used: f64) -> anyhow::Result<Vec<f64>> {
            anyhow::bail!("PJRT backend not compiled in")
        }

        pub fn edge_lm_burn(&self, _chunks: usize) -> anyhow::Result<f32> {
            anyhow::bail!("PJRT backend not compiled in")
        }

        pub fn has_edge_lm(&self) -> bool {
            false
        }
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::*;
    use crate::config::simparams::FEAT_DIM;
    // lint:allow(hash_collection): PJRT executable table is keyed lookup only
    use std::collections::HashMap;
    use std::path::PathBuf;

    /// One-thread PJRT engine over the artifact set.
    pub struct PjrtEngine {
        client: xla::PjRtClient,
        /// batch size -> compiled router executable.
        // lint:allow(hash_collection): keyed by batch size, never iterated
        routers: HashMap<usize, xla::PjRtLoadedExecutable>,
        edge_lm: Option<xla::PjRtLoadedExecutable>,
        /// Reused edge-LM input activations.
        edge_lm_input: Vec<f32>,
        pub artifacts_dir: PathBuf,
    }

    impl PjrtEngine {
        /// Load and compile every artifact under `artifacts_dir`.
        pub fn load(artifacts_dir: &Path) -> anyhow::Result<PjrtEngine> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
            // lint:allow(hash_collection): populated once, looked up by key
            let mut routers = HashMap::new();
            for b in ROUTER_BATCHES {
                let path = artifacts_dir.join(format!("router_b{b}.hlo.txt"));
                routers.insert(b, compile_hlo(&client, &path)?);
            }
            let edge_path = artifacts_dir.join("edge_lm.hlo.txt");
            let edge_lm =
                if edge_path.exists() { Some(compile_hlo(&client, &edge_path)?) } else { None };
            // Deterministic pseudo-activations for the burn input.
            let edge_lm_input: Vec<f32> = (0..EDGE_LM_T * EDGE_LM_D)
                .map(|i| ((i as f32 * 0.37).sin()) * 0.5)
                .collect();
            Ok(PjrtEngine {
                client,
                routers,
                edge_lm,
                edge_lm_input,
                artifacts_dir: artifacts_dir.to_path_buf(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Smallest compiled batch size that fits `n` rows (falls back to the
        /// largest and chunks when `n` exceeds it).
        pub fn pick_batch(&self, n: usize) -> usize {
            pick_batch_size(n)
        }

        /// Score a frontier: `u_hat` per feature row, shared `c_used` (Eq. 8).
        ///
        /// Rows are padded to the compiled batch; results sliced back. Inputs
        /// larger than the biggest batch are processed in chunks.
        pub fn score(&self, feats: &[Features], c_used: f64) -> anyhow::Result<Vec<f64>> {
            let mut out = Vec::with_capacity(feats.len());
            let max_b = *ROUTER_BATCHES.last().unwrap();
            let mut start = 0;
            while start < feats.len() {
                let end = (start + max_b).min(feats.len());
                out.extend(self.score_chunk(&feats[start..end], c_used)?);
                start = end;
            }
            Ok(out)
        }

        fn score_chunk(&self, feats: &[Features], c_used: f64) -> anyhow::Result<Vec<f64>> {
            let n = feats.len();
            let b = self.pick_batch(n);
            let exe = self.routers.get(&b).expect("batch executable");

            let mut flat = vec![0.0f32; b * FEAT_DIM];
            for (i, f) in feats.iter().enumerate() {
                flat[i * FEAT_DIM..(i + 1) * FEAT_DIM].copy_from_slice(f);
            }
            let feats_lit = xla::Literal::vec1(&flat)
                .reshape(&[b as i64, FEAT_DIM as i64])
                .map_err(|e| anyhow::anyhow!("reshape feats: {e:?}"))?;
            let c = vec![c_used as f32; b];
            let c_lit = xla::Literal::vec1(&c)
                .reshape(&[b as i64, 1])
                .map_err(|e| anyhow::anyhow!("reshape c_used: {e:?}"))?;

            let result = exe
                .execute::<xla::Literal>(&[feats_lit, c_lit])
                .map_err(|e| anyhow::anyhow!("router execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("router output sync: {e:?}"))?;
            let tuple = result.to_tuple1().map_err(|e| anyhow::anyhow!("router tuple: {e:?}"))?;
            let vals: Vec<f32> =
                tuple.to_vec().map_err(|e| anyhow::anyhow!("router to_vec: {e:?}"))?;
            anyhow::ensure!(vals.len() == b, "router output len {} != batch {b}", vals.len());
            Ok(vals[..n].iter().map(|&v| v as f64).collect())
        }

        /// Run `chunks` edge-LM forward passes (the simulated edge executor's
        /// compute). Returns the checksum of the last logits (keeps the work
        /// observable and un-optimizable).
        pub fn edge_lm_burn(&self, chunks: usize) -> anyhow::Result<f32> {
            let Some(exe) = &self.edge_lm else {
                anyhow::bail!("edge_lm artifact not loaded");
            };
            let mut checksum = 0.0f32;
            for _ in 0..chunks.max(1) {
                let x = xla::Literal::vec1(&self.edge_lm_input)
                    .reshape(&[EDGE_LM_T as i64, EDGE_LM_D as i64])
                    .map_err(|e| anyhow::anyhow!("edge_lm reshape: {e:?}"))?;
                let result = exe
                    .execute::<xla::Literal>(&[x])
                    .map_err(|e| anyhow::anyhow!("edge_lm execute: {e:?}"))?[0][0]
                    .to_literal_sync()
                    .map_err(|e| anyhow::anyhow!("edge_lm sync: {e:?}"))?;
                let logits: Vec<f32> = result
                    .to_tuple1()
                    .map_err(|e| anyhow::anyhow!("edge_lm tuple: {e:?}"))?
                    .to_vec()
                    .map_err(|e| anyhow::anyhow!("edge_lm to_vec: {e:?}"))?;
                checksum = logits.iter().take(8).sum();
            }
            Ok(checksum)
        }

        pub fn has_edge_lm(&self) -> bool {
            self.edge_lm.is_some()
        }
    }

    fn compile_hlo(
        client: &xla::PjRtClient,
        path: &Path,
    ) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        anyhow::ensure!(
            path.exists(),
            "artifact {} missing - run `make artifacts` first",
            path.display()
        );
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_actionable_error() {
        let err = PjrtEngine::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn batch_selection_shared_by_both_backends() {
        assert_eq!(pick_batch_size(1), 1);
        assert_eq!(pick_batch_size(5), 8);
        assert_eq!(pick_batch_size(8), 8);
        assert_eq!(pick_batch_size(9), 32);
        assert_eq!(pick_batch_size(100), 32);
    }
}

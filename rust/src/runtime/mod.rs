//! Runtime layer: PJRT CPU client wrapper over the AOT artifacts
//! (`artifacts/*.hlo.txt`) and the thread-isolated scoring service the
//! multithreaded coordinator uses on the request path.
//!
//! Python authors and lowers the computations (`make artifacts`); this
//! module loads HLO *text* via `HloModuleProto::from_text_file` (the
//! id-safe interchange — see DESIGN.md) and compiles once at startup.

pub mod client;
pub mod service;

pub use client::{PjrtEngine, EDGE_LM_D, EDGE_LM_T, ROUTER_BATCHES};
pub use service::RouterService;

//! Thread-isolated PJRT scoring service.
//!
//! The `xla` wrapper types are not `Send`, so a dedicated service thread
//! owns the [`PjrtEngine`]; coordinator threads talk to it over an mpsc
//! channel. This is the production shape of a model-scoring sidecar: one
//! compiled-artifact owner, many request producers.

use super::client::PjrtEngine;
use crate::embed::Features;
use crate::router::predictor::UtilityPredictor;
use std::path::Path;
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;

enum Request {
    Score { feats: Vec<Features>, c_used: f64, reply: Sender<anyhow::Result<Vec<f64>>> },
    EdgeBurn { chunks: usize, reply: Sender<anyhow::Result<f32>> },
    Platform { reply: Sender<String> },
    Shutdown,
}

/// Send+Sync handle to the PJRT service thread.
pub struct RouterService {
    tx: Mutex<Sender<Request>>,
    handle: Option<JoinHandle<()>>,
    has_edge_lm: bool,
}

impl RouterService {
    /// Start the service: loads + compiles artifacts on the service thread,
    /// failing fast if any artifact is missing or broken.
    pub fn start(artifacts_dir: &Path) -> anyhow::Result<RouterService> {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<bool>>();
        let dir = artifacts_dir.to_path_buf();
        let handle = std::thread::Builder::new()
            .name("pjrt-router-service".into())
            // lint:allow(thread_spawn): dedicated PJRT service thread, joined on Drop
            .spawn(move || {
                let engine = match PjrtEngine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(e.has_edge_lm()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Score { feats, c_used, reply } => {
                            let _ = reply.send(engine.score(&feats, c_used));
                        }
                        Request::EdgeBurn { chunks, reply } => {
                            let _ = reply.send(engine.edge_lm_burn(chunks));
                        }
                        Request::Platform { reply } => {
                            let _ = reply.send(engine.platform());
                        }
                        Request::Shutdown => break,
                    }
                }
            })?;
        let has_edge_lm = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("PJRT service thread died during startup"))??;
        Ok(RouterService { tx: Mutex::new(tx), handle: Some(handle), has_edge_lm })
    }

    fn send(&self, req: Request) {
        self.tx.lock().expect("service tx poisoned").send(req).expect("PJRT service gone");
    }

    /// Batched utility scoring through the AOT router artifact.
    pub fn score(&self, feats: &[Features], c_used: f64) -> anyhow::Result<Vec<f64>> {
        let (reply, rx) = channel();
        self.send(Request::Score { feats: feats.to_vec(), c_used, reply });
        rx.recv().map_err(|_| anyhow::anyhow!("PJRT service dropped reply"))?
    }

    /// Run edge-LM forward chunks (burn hook).
    pub fn edge_burn(&self, chunks: usize) -> anyhow::Result<f32> {
        let (reply, rx) = channel();
        self.send(Request::EdgeBurn { chunks, reply });
        rx.recv().map_err(|_| anyhow::anyhow!("PJRT service dropped reply"))?
    }

    pub fn platform(&self) -> String {
        let (reply, rx) = channel();
        self.send(Request::Platform { reply });
        rx.recv().unwrap_or_else(|_| "unknown".into())
    }

    pub fn has_edge_lm(&self) -> bool {
        self.has_edge_lm
    }
}

impl Drop for RouterService {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Request::Shutdown);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl UtilityPredictor for RouterService {
    fn predict(&self, feats: &[Features], c_used: f64) -> Vec<f64> {
        // Scoring failures surface as "never offload" rather than a crash on
        // the serving path; the error is logged once per call site.
        match self.score(feats, c_used) {
            Ok(v) => v,
            Err(e) => {
                // lint:allow(print_in_lib): serving-path degradation must be visible
                eprintln!("[runtime] router scoring failed: {e}; defaulting to edge");
                vec![0.0; feats.len()]
            }
        }
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }
}

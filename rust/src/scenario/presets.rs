//! Canonical scenario specs for the repo's standing experiments.
//!
//! Each constructor here is the **single source of truth** for one
//! documented scenario: the `eval` experiment, the runnable example, and
//! the shipped `scenarios/*.json` file are all derived from it, so the
//! three can never drift apart (`rust/tests/scenario.rs` pins the JSON
//! files against these constructors, and the experiment tables run the
//! exact sessions they build).

use super::{
    CacheSpec, EngineSpec, PolicySpec, ScenarioSpec, SweepAxis, SweepField, SweepSpec,
    TenantSpec, TopologySpec, WorkloadSpec,
};
use crate::cache::CachePolicyKind;
use crate::fault::{FaultConfig, OutageWindow, ResilienceConfig};
use crate::obs::ObserveConfig;
use crate::workload::trace::{ArrivalProcess, ZipfMix};
use crate::workload::Benchmark;

/// Knobs of the plain fleet-simulation scenario (shared edge/cloud pools,
/// homogeneous policy, optional per-tenant dollar caps).
#[derive(Debug, Clone)]
pub struct FleetSimKnobs {
    pub n_tenants: usize,
    pub edge_workers: usize,
    pub cloud_workers: usize,
    pub admission_limit: usize,
    /// Per-tenant dollar cap; `None` = unlimited.
    pub tenant_cap: Option<f64>,
    pub record_trace: bool,
    /// Observability recorders (spans / metrics); `None` = fully off, the
    /// preset keeps its pre-observability bytes.
    pub observe: Option<ObserveConfig>,
}

impl Default for FleetSimKnobs {
    fn default() -> Self {
        FleetSimKnobs {
            n_tenants: 3,
            edge_workers: 8,
            cloud_workers: 16,
            admission_limit: 64,
            tenant_cap: None,
            record_trace: true,
            observe: None,
        }
    }
}

/// The `fleet_sim` scenario: a Poisson multi-tenant workload on shared
/// pools under the learned router — the canonical determinism demo
/// (`examples/fleet_sim.rs` runs it twice and compares traces).
pub fn fleet_sim(
    bench: Benchmark,
    n: usize,
    rate: f64,
    seed: u64,
    knobs: &FleetSimKnobs,
) -> ScenarioSpec {
    let tenants = (0..knobs.n_tenants.max(1))
        .map(|i| {
            let name = format!("tenant-{i}");
            match knobs.tenant_cap {
                Some(cap) if cap.is_finite() => TenantSpec::capped(&name, cap),
                _ => TenantSpec::unlimited(&name),
            }
        })
        .collect();
    ScenarioSpec {
        name: "fleet_sim".into(),
        seed,
        topology: TopologySpec {
            edge_workers: knobs.edge_workers,
            cloud_workers: knobs.cloud_workers,
            admission_limit: knobs.admission_limit,
            global_k_cap: None,
            shards: 1,
            tenants,
        },
        workload: WorkloadSpec {
            benchmark: bench,
            n,
            arrival: ArrivalProcess::Poisson { rate },
            zipf: None,
        },
        engine: EngineSpec {
            record_trace: knobs.record_trace,
            observe: knobs.observe.clone(),
            ..Default::default()
        },
    }
}

/// The `fleet_serve` contention-sweep scenario: three tenants (one
/// unlimited anchor, two metered) on an 8-edge / 16-cloud fleet; the
/// experiment sweeps the Poisson rate from idle to saturated.
pub fn fleet_serve(bench: Benchmark, n: usize, rate: f64, seed: u64) -> ScenarioSpec {
    ScenarioSpec {
        name: "fleet_serve".into(),
        seed,
        topology: TopologySpec {
            edge_workers: 8,
            cloud_workers: 16,
            admission_limit: 64,
            global_k_cap: None,
            shards: 1,
            tenants: vec![
                TenantSpec::unlimited("anchor"),
                TenantSpec::capped("metered", 0.05),
                TenantSpec::capped("capped", 0.005),
            ],
        },
        workload: WorkloadSpec {
            benchmark: bench,
            n,
            arrival: ArrivalProcess::Poisson { rate },
            zipf: None,
        },
        engine: EngineSpec { record_trace: false, ..Default::default() },
    }
}

/// Knobs of the canonical mixed-policy scenario (see [`mixed_policy`]).
#[derive(Debug, Clone)]
pub struct MixedPolicyKnobs {
    pub edge_workers: usize,
    pub cloud_workers: usize,
    pub hedge: bool,
    pub hedge_threshold: f64,
    pub record_trace: bool,
}

impl Default for MixedPolicyKnobs {
    fn default() -> Self {
        MixedPolicyKnobs {
            edge_workers: 4,
            cloud_workers: 16,
            hedge: false,
            hedge_threshold: 0.55,
            record_trace: false,
        }
    }
}

/// Canonical 3-tenant mixed-policy fleet, shared by the
/// `fleet_mixed_policy` experiment and `examples/fleet_mixed_policy.rs`.
/// Heterogeneous tenants: the learned router (engine default), a
/// conservative fixed threshold (strands pivotal work on the edge —
/// hedging's best case), and a hard edge pin with a small dollar pool
/// that only hedged speculation can spend from.
pub fn mixed_policy(
    bench: Benchmark,
    n: usize,
    rate: f64,
    seed: u64,
    knobs: &MixedPolicyKnobs,
) -> ScenarioSpec {
    ScenarioSpec {
        name: "fleet_mixed_policy".into(),
        seed,
        topology: TopologySpec {
            edge_workers: knobs.edge_workers,
            cloud_workers: knobs.cloud_workers,
            admission_limit: 64,
            global_k_cap: None,
            shards: 1,
            tenants: vec![
                TenantSpec::unlimited("learned"),
                TenantSpec::unlimited("fixed-0.65").with_policy(PolicySpec::Fixed(0.65)),
                TenantSpec::capped("edge-pinned", 0.02).with_policy(PolicySpec::AllEdge),
            ],
        },
        workload: WorkloadSpec {
            benchmark: bench,
            n,
            arrival: ArrivalProcess::Poisson { rate },
            zipf: None,
        },
        engine: EngineSpec {
            hedge: knobs.hedge,
            hedge_threshold: knobs.hedge_threshold,
            record_trace: knobs.record_trace,
            ..Default::default()
        },
    }
}

/// Knobs of the canonical cached-Zipf fleet scenario (see
/// [`fleet_cache`]).
#[derive(Debug, Clone)]
pub struct FleetCacheKnobs {
    /// Result-cache capacity per partition; 0 disables the cache.
    pub capacity: usize,
    pub policy: CachePolicyKind,
    /// Fleet-wide shared tier on top of per-tenant partitions.
    pub shared_tier: bool,
    pub edge_workers: usize,
    pub cloud_workers: usize,
    /// Zipf popularity skew and prototype-pool size of the workload.
    pub zipf_exponent: f64,
    pub zipf_distinct: usize,
    pub record_trace: bool,
}

impl Default for FleetCacheKnobs {
    fn default() -> Self {
        FleetCacheKnobs {
            capacity: 256,
            policy: CachePolicyKind::Lru,
            shared_tier: true,
            edge_workers: 4,
            cloud_workers: 16,
            zipf_exponent: 1.1,
            zipf_distinct: 8,
            record_trace: false,
        }
    }
}

/// Canonical cached-Zipf fleet, shared by the `fleet_cache` experiment
/// and `examples/fleet_cache.rs`: two unlimited tenants under the learned
/// router, a Zipf-repeated workload, and a result cache with per-tenant
/// partitions plus the shared global tier.
pub fn fleet_cache(
    bench: Benchmark,
    n: usize,
    rate: f64,
    seed: u64,
    knobs: &FleetCacheKnobs,
) -> ScenarioSpec {
    ScenarioSpec {
        name: "fleet_cache".into(),
        seed,
        topology: TopologySpec {
            edge_workers: knobs.edge_workers,
            cloud_workers: knobs.cloud_workers,
            admission_limit: 64,
            global_k_cap: None,
            shards: 1,
            tenants: vec![TenantSpec::unlimited("a"), TenantSpec::unlimited("b")],
        },
        workload: WorkloadSpec {
            benchmark: bench,
            n,
            arrival: ArrivalProcess::Poisson { rate },
            zipf: Some(ZipfMix::new(knobs.zipf_exponent, knobs.zipf_distinct)),
        },
        engine: EngineSpec {
            record_trace: knobs.record_trace,
            cache: (knobs.capacity > 0).then(|| CacheSpec {
                capacity: knobs.capacity,
                policy: knobs.policy,
                shared_tier: knobs.shared_tier,
            }),
            ..Default::default()
        },
    }
}

/// The `fleet_sharded` scenario: the [`fleet_sim`] fleet partitioned
/// across 4 kernel shards — the canonical sharded-determinism demo.
/// Shipped as `scenarios/fleet_sharded.json`; `scripts/verify.sh` runs it
/// at `--shards 1` and `--shards 4` and checks the reports differ (the
/// override takes effect) while reruns stay byte-identical. Tracing is
/// off: the point of sharding is throughput, and the per-query trace is
/// already pinned by the golden fleet.
pub fn fleet_sharded(bench: Benchmark, n: usize, rate: f64, seed: u64) -> ScenarioSpec {
    let knobs = FleetSimKnobs { record_trace: false, ..Default::default() };
    let mut spec = fleet_sim(bench, n, rate, seed, &knobs);
    spec.name = "fleet_sharded".into();
    spec.topology.shards = 4;
    spec
}

/// The `fleet_faulty` scenario: the [`fleet_sim`] fleet under the fault
/// layer — a mid-run cloud outage window, per-side transient failure
/// probabilities, and straggler tail inflation, handled by bounded
/// retries with backoff, cross-side failover, a generous per-subtask
/// timeout, and graceful degradation. Shipped as
/// `scenarios/fleet_faulty.json`; `scripts/verify.sh` runs it twice (and
/// once at `--threads 4`) and checks the report bytes match — fault
/// realizations are drawn from per-attempt forked streams, so the whole
/// scenario is byte-reproducible. Tracing is off (the degradation path
/// traces are pinned by `rust/tests/faults.rs`).
pub fn fleet_faulty(bench: Benchmark, n: usize, rate: f64, seed: u64) -> ScenarioSpec {
    let knobs = FleetSimKnobs { record_trace: false, ..Default::default() };
    let mut spec = fleet_sim(bench, n, rate, seed, &knobs);
    spec.name = "fleet_faulty".into();
    spec.engine.faults = Some(FaultConfig {
        edge_fail_p: 0.02,
        cloud_fail_p: 0.05,
        straggler_p: 0.02,
        straggler_mult: 4.0,
        seed: 7,
        outages: vec![OutageWindow { cloud: true, start: 40.0, end: 80.0 }],
    });
    spec.engine.resilience = Some(ResilienceConfig {
        timeout: Some(60.0),
        max_retries: 3,
        backoff_base: 0.05,
        backoff_jitter: 0.1,
        failover_after: 2,
    });
    spec
}

/// The `fleet_serve` contention grid as a declarative sweep: the
/// [`fleet_serve`] scenario with the Poisson arrival rate swept from idle
/// to saturated — the exact grid the `fleet_serve` experiment tabulates
/// (each cell is `fleet_serve(bench, n, rate, seed)` for one swept rate).
pub fn fleet_serve_sweep(bench: Benchmark, n: usize, seed: u64) -> SweepSpec {
    SweepSpec {
        name: "fleet_serve_sweep".into(),
        base: fleet_serve(bench, n, 0.5, seed),
        axes: vec![SweepAxis {
            field: SweepField::ArrivalRate,
            values: vec![0.1, 0.25, 0.5, 1.0, 2.0],
        }],
    }
}

/// The `fleet_cache` capacity grid as a declarative sweep: the cached-Zipf
/// fleet of [`fleet_cache`] with the result-cache capacity swept from off
/// (0 — the baseline cell) through the working set. Shipped as
/// `scenarios/fleet_cache_sweep.json`; the `fleet_cache` experiment runs
/// this grid across the thread pool.
pub fn fleet_cache_sweep(
    bench: Benchmark,
    n: usize,
    rate: f64,
    seed: u64,
    knobs: &FleetCacheKnobs,
) -> SweepSpec {
    SweepSpec {
        name: "fleet_cache_sweep".into(),
        base: fleet_cache(bench, n, rate, seed, knobs),
        axes: vec![SweepAxis {
            field: SweepField::CacheCapacity,
            values: vec![0.0, 16.0, 64.0, 256.0],
        }],
    }
}

/// The golden-trace fleet (`rust/tests/golden/fleet_trace.txt`) as a
/// scenario: 12 GPQA queries, periodic 1.5s arrivals, three tenants with
/// the pinned dollar caps, 4 edge / 8 cloud workers, seed 1234. Running
/// this spec through a session must reproduce the pinned trace
/// byte-for-byte (pinned by `rust/tests/scenario.rs`).
pub fn golden_fleet() -> ScenarioSpec {
    ScenarioSpec {
        name: "golden_fleet".into(),
        seed: 1234,
        topology: TopologySpec {
            edge_workers: 4,
            cloud_workers: 8,
            admission_limit: 0,
            global_k_cap: None,
            shards: 1,
            tenants: vec![
                TenantSpec::unlimited("anchor"),
                TenantSpec::capped("metered", 0.02),
                TenantSpec::capped("capped", 0.001),
            ],
        },
        workload: WorkloadSpec {
            benchmark: Benchmark::Gpqa,
            n: 12,
            arrival: ArrivalProcess::Periodic { gap: 1.5 },
            zipf: None,
        },
        engine: EngineSpec::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::ScenarioSpec;

    #[test]
    fn presets_roundtrip_through_json() {
        let specs = [
            fleet_sim(Benchmark::Gpqa, 60, 0.5, 11, &FleetSimKnobs::default()),
            fleet_serve(Benchmark::Gpqa, 120, 0.5, 11),
            mixed_policy(Benchmark::Gpqa, 90, 0.6, 11, &MixedPolicyKnobs::default()),
            fleet_cache(Benchmark::Gpqa, 120, 0.5, 11, &FleetCacheKnobs::default()),
            fleet_sharded(Benchmark::Gpqa, 240, 2.0, 11),
            fleet_faulty(Benchmark::Gpqa, 60, 0.5, 11),
            golden_fleet(),
        ];
        for spec in specs {
            let back = ScenarioSpec::parse(&spec.render()).expect("preset parses");
            assert_eq!(back, spec, "{} round trip", spec.name);
        }
    }

    #[test]
    fn sweep_presets_roundtrip_through_json() {
        let sweeps = [
            fleet_serve_sweep(Benchmark::Gpqa, 120, 11),
            fleet_cache_sweep(Benchmark::Gpqa, 120, 0.5, 11, &FleetCacheKnobs::default()),
        ];
        for sweep in sweeps {
            let back = SweepSpec::parse(&sweep.render()).expect("sweep preset parses");
            assert_eq!(back, sweep, "{} round trip", sweep.name);
            // Every cell resolves to a valid scenario.
            assert!(!sweep.cells().unwrap().is_empty());
        }
    }
}

//! Declarative Scenario API: JSON experiment descriptions resolved into
//! runnable sessions over the unified simulation kernel.
//!
//! The engine used to expose five divergent hand-wired entrypoints
//! (`execute_query`, `run_fleet`, `serve`, `serve_fleet`,
//! `serve_fleet_zipf`); defining a new serving scenario meant writing
//! Rust. [`ScenarioSpec`] replaces that with data: a serde-free,
//! JSON-serializable (via [`crate::util::json`]) description of
//!
//! * **topology** — worker pools, admission limit, tenants with dollar
//!   caps and optional per-tenant routing-policy overrides, global dollar
//!   ceiling ([`TopologySpec`]);
//! * **workload** — benchmark, query count, arrival process, optional
//!   Zipf popularity mix ([`WorkloadSpec`]);
//! * **engine** — default routing policy, chain mode, frontier batching,
//!   hedged dispatch, result cache ([`EngineSpec`]).
//!
//! [`ScenarioSpec::build`] resolves the spec against a utility predictor
//! into a [`Session`]; [`Session::run`] executes it on the kernel and
//! returns a [`Report`]. Everything is deterministic in the spec (the
//! seed is part of it), and `Session::run` clones the tenant pools per
//! run, so re-running a session reproduces the event trace byte-for-byte.
//!
//! Canonical specs for the repo's standing experiments live in
//! [`presets`] and are shipped as `scenarios/*.json`; the CLI runs any
//! spec file via `hybridflow run --scenario <file.json>`.
//!
//! Serialization contract: [`ScenarioSpec::render`] emits canonical JSON
//! (sorted keys, pretty-printed) and `parse(render(parse(text)))` is a
//! fixpoint — pinned for every shipped spec by `rust/tests/scenario.rs`.

pub mod presets;
pub mod sweep;

pub use sweep::{SweepAxis, SweepCellResult, SweepField, SweepReport, SweepSpec};

use crate::budget::TenantPool;
use crate::cache::{CachePolicyKind, SubtaskCache};
use crate::config::simparams::SimParams;
use crate::fault::{FaultConfig, OutageWindow, ResilienceConfig};
use crate::obs::ObserveConfig;
use crate::models::SimExecutor;
use crate::pipeline::{HybridFlowPipeline, PipelineConfig};
use crate::planner::synthetic::SyntheticPlanner;
use crate::router::{RoutePolicy, UtilityPredictor};
use crate::sim::{run_fleet, run_fleet_sharded, FleetArrival, FleetConfig, FleetReport};
use crate::util::json::Json;
use crate::workload::trace::{ArrivalProcess, ZipfMix};
use crate::workload::{generate_queries, Benchmark};
use std::sync::Arc;

/// The report a scenario session produces (the kernel's aggregate run
/// outcome: per-query results, tenant pools, latency summaries, cache and
/// hedge counters, and the byte-stable event trace).
pub type Report = FleetReport;

/// Declarative routing-policy selection for scenario files. This is the
/// string-level mirror of [`RoutePolicy`] (custom threshold schedules
/// stay a Rust-level concern): `hybridflow`, `hybridflow_eq27`,
/// `hybridflow_calibrated`, `all_edge`, `all_cloud`, `oracle`,
/// `random:<p>`, `fixed:<tau>`.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicySpec {
    HybridFlow,
    HybridFlowEq27,
    HybridFlowCalibrated,
    AllEdge,
    AllCloud,
    Oracle,
    Random(f64),
    Fixed(f64),
}

impl PolicySpec {
    pub fn parse(s: &str) -> Option<PolicySpec> {
        let lower = s.trim().to_ascii_lowercase();
        match lower.as_str() {
            "hybridflow" => Some(PolicySpec::HybridFlow),
            "hybridflow_eq27" => Some(PolicySpec::HybridFlowEq27),
            "hybridflow_calibrated" => Some(PolicySpec::HybridFlowCalibrated),
            "all_edge" | "edge" => Some(PolicySpec::AllEdge),
            "all_cloud" | "cloud" => Some(PolicySpec::AllCloud),
            "oracle" => Some(PolicySpec::Oracle),
            other => {
                if let Some(p) = other.strip_prefix("random:") {
                    let p = p.parse::<f64>().ok()?;
                    return (0.0..=1.0).contains(&p).then_some(PolicySpec::Random(p));
                }
                if let Some(t) = other.strip_prefix("fixed:") {
                    let t = t.parse::<f64>().ok()?;
                    return t.is_finite().then_some(PolicySpec::Fixed(t));
                }
                None
            }
        }
    }

    /// Canonical string form (parse-render fixpoint).
    pub fn render(&self) -> String {
        match self {
            PolicySpec::HybridFlow => "hybridflow".into(),
            PolicySpec::HybridFlowEq27 => "hybridflow_eq27".into(),
            PolicySpec::HybridFlowCalibrated => "hybridflow_calibrated".into(),
            PolicySpec::AllEdge => "all_edge".into(),
            PolicySpec::AllCloud => "all_cloud".into(),
            PolicySpec::Oracle => "oracle".into(),
            PolicySpec::Random(p) => format!("random:{p}"),
            PolicySpec::Fixed(t) => format!("fixed:{t}"),
        }
    }

    /// Resolve into the engine's policy configuration.
    pub fn build(&self, sp: &SimParams) -> RoutePolicy {
        match self {
            PolicySpec::HybridFlow => RoutePolicy::hybridflow(sp),
            PolicySpec::HybridFlowEq27 => RoutePolicy::hybridflow_eq27(sp),
            PolicySpec::HybridFlowCalibrated => RoutePolicy::hybridflow_calibrated(sp),
            PolicySpec::AllEdge => RoutePolicy::AllEdge,
            PolicySpec::AllCloud => RoutePolicy::AllCloud,
            PolicySpec::Oracle => RoutePolicy::Oracle,
            PolicySpec::Random(p) => RoutePolicy::Random(*p),
            PolicySpec::Fixed(t) => RoutePolicy::FixedThreshold(*t),
        }
    }
}

/// One tenant of the scenario topology.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Cloud-dollar allotment; `None` = unlimited (JSON `null`).
    pub k_cap: Option<f64>,
    /// Routing-policy override; `None` falls back to the engine default.
    pub policy: Option<PolicySpec>,
}

impl TenantSpec {
    pub fn unlimited(name: &str) -> TenantSpec {
        TenantSpec { name: name.into(), k_cap: None, policy: None }
    }

    pub fn capped(name: &str, k_cap: f64) -> TenantSpec {
        TenantSpec { name: name.into(), k_cap: Some(k_cap), policy: None }
    }

    pub fn with_policy(mut self, policy: PolicySpec) -> TenantSpec {
        self.policy = Some(policy);
        self
    }
}

/// Worker pools, admission, and the tenant list.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologySpec {
    pub edge_workers: usize,
    pub cloud_workers: usize,
    /// Maximum queries in service at once; 0 = unlimited.
    pub admission_limit: usize,
    /// Fleet-wide dollar ceiling; `None` = unlimited (JSON `null`).
    pub global_k_cap: Option<f64>,
    /// Independent fleet shards, each modeling its own worker pools,
    /// cache, admission queue, and `1/shards` of every dollar cap (see
    /// [`crate::sim::run_fleet_sharded`]). `1` (the default when the
    /// field is absent) is the single-kernel fleet, byte-identical to the
    /// pre-sharding engine.
    pub shards: usize,
    pub tenants: Vec<TenantSpec>,
}

/// Benchmark, size, arrival process, and optional Zipf repetition of the
/// query stream. Arrivals are assigned to tenants round-robin.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub benchmark: Benchmark,
    pub n: usize,
    pub arrival: ArrivalProcess,
    pub zipf: Option<ZipfMix>,
}

impl WorkloadSpec {
    /// Materialize the arrival list: `n` queries from the benchmark
    /// generator (Zipf-rewritten when configured), timestamps from the
    /// arrival process, tenants round-robin. Deterministic in
    /// `(self, n_tenants, seed)` — the exact construction the historical
    /// `serve_fleet` / `serve_fleet_zipf` entrypoints used, so scenario
    /// runs are byte-identical to the hand-wired experiments.
    pub fn arrivals(&self, n_tenants: usize, seed: u64) -> Vec<FleetArrival> {
        let n_tenants = n_tenants.max(1);
        let times = self.arrival.sample(self.n, seed);
        let base = generate_queries(self.benchmark, self.n, seed);
        let queries = match &self.zipf {
            Some(z) => z.apply(&base, seed),
            None => base,
        };
        queries
            .into_iter()
            .zip(times)
            .enumerate()
            .map(|(i, (query, time))| FleetArrival { time, tenant: i % n_tenants, query })
            .collect()
    }
}

/// Cross-query result-cache configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheSpec {
    /// Entries per partition; 0 disables the cache.
    pub capacity: usize,
    pub policy: CachePolicyKind,
    /// Fleet-wide shared tier on top of per-tenant partitions.
    pub shared_tier: bool,
}

/// Engine options: default routing policy plus every scheduling knob.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineSpec {
    /// Default routing policy (tenants may override per
    /// [`TenantSpec::policy`]).
    pub policy: PolicySpec,
    pub chain_mode: bool,
    pub batch_frontier: bool,
    pub hedge: bool,
    pub hedge_threshold: f64,
    /// Planner subtask cap (Def. C.2 rule 5).
    pub n_max: usize,
    pub record_trace: bool,
    pub cache: Option<CacheSpec>,
    /// Structured observability (spans, metrics time series, critical
    /// paths). `None` is fully off — the kernel takes the exact
    /// uninstrumented code path and the key is omitted from the rendered
    /// spec, so pre-observability spec files round-trip unchanged.
    pub observe: Option<ObserveConfig>,
    /// Deterministic fault injection (transient failures, outage windows,
    /// stragglers — [`FaultConfig`]). When both this and `resilience` are
    /// `None` (the default; keys omitted from the rendered spec) the
    /// kernel takes the exact pre-fault code path, so pre-fault spec files
    /// round-trip unchanged and keep their golden bytes.
    pub faults: Option<FaultConfig>,
    /// Resilience policy (per-subtask timeout, bounded retries with
    /// backoff, cross-side failover, graceful degradation —
    /// [`ResilienceConfig`]). The fault layer activates when *either*
    /// block is present; a missing half takes its defaults.
    pub resilience: Option<ResilienceConfig>,
}

impl Default for EngineSpec {
    fn default() -> Self {
        let sp = SimParams::default();
        EngineSpec {
            policy: PolicySpec::HybridFlow,
            chain_mode: false,
            batch_frontier: true,
            hedge: false,
            hedge_threshold: 0.55,
            n_max: sp.nmax,
            record_trace: true,
            cache: None,
            observe: None,
            faults: None,
            resilience: None,
        }
    }
}

/// A complete declarative scenario: everything a run needs except the
/// utility predictor (a loaded artifact, injected at
/// [`ScenarioSpec::build`] time).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    /// Run seed. JSON numbers are f64, so seeds above 2^53 do not
    /// round-trip exactly through spec files; keep file-borne seeds in
    /// the exactly-representable range (every shipped spec does).
    pub seed: u64,
    pub topology: TopologySpec,
    pub workload: WorkloadSpec,
    pub engine: EngineSpec,
}

impl ScenarioSpec {
    // ------------------------------------------------------------------
    // JSON (de)serialization — util/json, serde-free.
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let tenants: Vec<Json> = self
            .topology
            .tenants
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("name", Json::Str(t.name.clone())),
                    ("k_cap", opt_num(t.k_cap)),
                    (
                        "policy",
                        t.policy.as_ref().map_or(Json::Null, |p| Json::Str(p.render())),
                    ),
                ])
            })
            .collect();
        let arrival = match &self.workload.arrival {
            ArrivalProcess::Poisson { rate } => Json::obj(vec![
                ("process", Json::Str("poisson".into())),
                ("rate", Json::Num(*rate)),
            ]),
            ArrivalProcess::Periodic { gap } => Json::obj(vec![
                ("process", Json::Str("periodic".into())),
                ("gap", Json::Num(*gap)),
            ]),
            ArrivalProcess::Trace(times) => Json::obj(vec![
                ("process", Json::Str("trace".into())),
                ("times", Json::from_f64_slice(times)),
            ]),
        };
        let zipf = self.workload.zipf.as_ref().map_or(Json::Null, |z| {
            Json::obj(vec![
                ("exponent", Json::Num(z.exponent)),
                ("distinct", Json::Num(z.distinct as f64)),
            ])
        });
        let cache = self.engine.cache.as_ref().map_or(Json::Null, |c| {
            Json::obj(vec![
                ("capacity", Json::Num(c.capacity as f64)),
                ("policy", Json::Str(c.policy.spec_label())),
                ("shared_tier", Json::Bool(c.shared_tier)),
            ])
        });
        let mut engine = vec![
            ("policy", Json::Str(self.engine.policy.render())),
            ("chain_mode", Json::Bool(self.engine.chain_mode)),
            ("batch_frontier", Json::Bool(self.engine.batch_frontier)),
            ("hedge", Json::Bool(self.engine.hedge)),
            ("hedge_threshold", Json::Num(self.engine.hedge_threshold)),
            ("n_max", Json::Num(self.engine.n_max as f64)),
            ("record_trace", Json::Bool(self.engine.record_trace)),
            ("cache", cache),
        ];
        // Emitted only when present, so pre-observability spec files keep
        // their exact rendered bytes (parse-render fixpoint).
        if let Some(o) = &self.engine.observe {
            engine.push((
                "observe",
                Json::obj(vec![
                    ("spans", Json::Bool(o.spans)),
                    ("metrics", Json::Bool(o.metrics)),
                    ("metrics_interval", Json::Num(o.metrics_interval)),
                ]),
            ));
        }
        // Same contract as `observe`: emitted only when present, so
        // pre-fault spec files keep their exact rendered bytes.
        if let Some(f) = &self.engine.faults {
            let outages: Vec<Json> = f
                .outages
                .iter()
                .map(|w| {
                    Json::obj(vec![
                        ("side", Json::Str(if w.cloud { "cloud" } else { "edge" }.into())),
                        ("start", Json::Num(w.start)),
                        ("end", Json::Num(w.end)),
                    ])
                })
                .collect();
            engine.push((
                "faults",
                Json::obj(vec![
                    ("edge_fail_p", Json::Num(f.edge_fail_p)),
                    ("cloud_fail_p", Json::Num(f.cloud_fail_p)),
                    ("straggler_p", Json::Num(f.straggler_p)),
                    ("straggler_mult", Json::Num(f.straggler_mult)),
                    ("seed", Json::Num(f.seed as f64)),
                    ("outages", Json::Arr(outages)),
                ]),
            ));
        }
        if let Some(r) = &self.engine.resilience {
            engine.push((
                "resilience",
                Json::obj(vec![
                    ("timeout", opt_num(r.timeout)),
                    ("max_retries", Json::Num(r.max_retries as f64)),
                    ("backoff_base", Json::Num(r.backoff_base)),
                    ("backoff_jitter", Json::Num(r.backoff_jitter)),
                    ("failover_after", Json::Num(r.failover_after as f64)),
                ]),
            ));
        }
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("seed", Json::Num(self.seed as f64)),
            (
                "topology",
                Json::obj(vec![
                    ("edge_workers", Json::Num(self.topology.edge_workers as f64)),
                    ("cloud_workers", Json::Num(self.topology.cloud_workers as f64)),
                    ("admission_limit", Json::Num(self.topology.admission_limit as f64)),
                    ("global_k_cap", opt_num(self.topology.global_k_cap)),
                    ("shards", Json::Num(self.topology.shards as f64)),
                    ("tenants", Json::Arr(tenants)),
                ]),
            ),
            (
                "workload",
                Json::obj(vec![
                    ("benchmark", Json::Str(self.workload.benchmark.name().into())),
                    ("n", Json::Num(self.workload.n as f64)),
                    ("arrival", arrival),
                    ("zipf", zipf),
                ]),
            ),
            ("engine", Json::obj(engine)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ScenarioSpec> {
        let name = req_str(j, "name")?.to_string();
        let seed = req_count(j, "seed")? as u64;

        let topo = j.get("topology").ok_or_else(|| missing("topology"))?;
        let tenants = topo
            .get("tenants")
            .and_then(Json::as_arr)
            .ok_or_else(|| missing("topology.tenants"))?
            .iter()
            .map(|t| {
                let policy = match t.get("policy") {
                    None | Some(Json::Null) => None,
                    Some(Json::Str(s)) => Some(
                        PolicySpec::parse(s)
                            .ok_or_else(|| anyhow::anyhow!("unknown tenant policy '{s}'"))?,
                    ),
                    Some(other) => anyhow::bail!("tenant policy must be a string, got {other:?}"),
                };
                Ok(TenantSpec {
                    name: req_str(t, "name")?.to_string(),
                    k_cap: opt_num_field(t, "k_cap")?,
                    policy,
                })
            })
            .collect::<anyhow::Result<Vec<TenantSpec>>>()?;
        anyhow::ensure!(!tenants.is_empty(), "scenario needs at least one tenant");
        let topology = TopologySpec {
            edge_workers: req_count(topo, "edge_workers")?,
            cloud_workers: req_count(topo, "cloud_workers")?,
            admission_limit: count_or(topo, "admission_limit", 0)?,
            global_k_cap: opt_num_field(topo, "global_k_cap")?,
            // Absent in pre-sharding spec files: default to the single
            // unsharded kernel.
            shards: count_or(topo, "shards", 1)?,
            tenants,
        };

        let wl = j.get("workload").ok_or_else(|| missing("workload"))?;
        let bench_name = req_str(wl, "benchmark")?;
        let benchmark = Benchmark::parse(bench_name)
            .ok_or_else(|| anyhow::anyhow!("unknown benchmark '{bench_name}'"))?;
        let arr = wl.get("arrival").ok_or_else(|| missing("workload.arrival"))?;
        let arrival = match req_str(arr, "process")? {
            "poisson" => {
                let rate = req_num(arr, "rate")?;
                anyhow::ensure!(rate > 0.0, "poisson rate must be positive");
                ArrivalProcess::Poisson { rate }
            }
            "periodic" => {
                let gap = req_num(arr, "gap")?;
                anyhow::ensure!(gap >= 0.0, "periodic gap must be non-negative");
                ArrivalProcess::Periodic { gap }
            }
            "trace" => {
                let times = arr
                    .get("times")
                    .and_then(Json::f64_array)
                    .ok_or_else(|| missing("workload.arrival.times"))?;
                ArrivalProcess::Trace(times)
            }
            other => anyhow::bail!("unknown arrival process '{other}' (poisson|periodic|trace)"),
        };
        let zipf = match wl.get("zipf") {
            None | Some(Json::Null) => None,
            Some(z) => {
                let exponent = req_num(z, "exponent")?;
                anyhow::ensure!(exponent >= 0.0, "zipf exponent must be non-negative");
                Some(ZipfMix::new(exponent, req_count(z, "distinct")?))
            }
        };
        let workload = WorkloadSpec { benchmark, n: req_count(wl, "n")?, arrival, zipf };

        let eng = j.get("engine").ok_or_else(|| missing("engine"))?;
        let policy_name = req_str(eng, "policy")?;
        let policy = PolicySpec::parse(policy_name)
            .ok_or_else(|| anyhow::anyhow!("unknown engine policy '{policy_name}'"))?;
        let cache = match eng.get("cache") {
            None | Some(Json::Null) => None,
            Some(c) => {
                let label = req_str(c, "policy")?;
                let kind = CachePolicyKind::parse(label).ok_or_else(|| {
                    anyhow::anyhow!("unknown cache policy '{label}' (lru|lfu|ttl[:secs])")
                })?;
                Some(CacheSpec {
                    capacity: req_count(c, "capacity")?,
                    policy: kind,
                    shared_tier: bool_or(c, "shared_tier", false)?,
                })
            }
        };
        let observe = match eng.get("observe") {
            None | Some(Json::Null) => None,
            Some(o) => {
                let d = ObserveConfig::default();
                Some(ObserveConfig {
                    spans: bool_or(o, "spans", d.spans)?,
                    metrics: bool_or(o, "metrics", d.metrics)?,
                    metrics_interval: num_or(o, "metrics_interval", d.metrics_interval)?,
                })
            }
        };
        let faults = match eng.get("faults") {
            None | Some(Json::Null) => None,
            Some(f) => {
                let d = FaultConfig::default();
                let outages = match f.get("outages") {
                    None | Some(Json::Null) => Vec::new(),
                    Some(Json::Arr(ws)) => ws
                        .iter()
                        .map(|w| {
                            let cloud = match req_str(w, "side")? {
                                "cloud" => true,
                                "edge" => false,
                                other => anyhow::bail!(
                                    "outage side must be 'edge' or 'cloud', got '{other}'"
                                ),
                            };
                            Ok(OutageWindow {
                                cloud,
                                start: req_num(w, "start")?,
                                end: req_num(w, "end")?,
                            })
                        })
                        .collect::<anyhow::Result<Vec<OutageWindow>>>()?,
                    Some(_) => anyhow::bail!("'faults.outages' must be an array"),
                };
                Some(FaultConfig {
                    edge_fail_p: num_or(f, "edge_fail_p", d.edge_fail_p)?,
                    cloud_fail_p: num_or(f, "cloud_fail_p", d.cloud_fail_p)?,
                    straggler_p: num_or(f, "straggler_p", d.straggler_p)?,
                    straggler_mult: num_or(f, "straggler_mult", d.straggler_mult)?,
                    seed: count_or(f, "seed", d.seed as usize)? as u64,
                    outages,
                })
            }
        };
        let resilience = match eng.get("resilience") {
            None | Some(Json::Null) => None,
            Some(r) => {
                let d = ResilienceConfig::default();
                Some(ResilienceConfig {
                    timeout: opt_num_field(r, "timeout")?,
                    max_retries: count_or(r, "max_retries", d.max_retries)?,
                    backoff_base: num_or(r, "backoff_base", d.backoff_base)?,
                    backoff_jitter: num_or(r, "backoff_jitter", d.backoff_jitter)?,
                    failover_after: count_or(r, "failover_after", d.failover_after)?,
                })
            }
        };
        let defaults = EngineSpec::default();
        let engine = EngineSpec {
            policy,
            chain_mode: bool_or(eng, "chain_mode", false)?,
            batch_frontier: bool_or(eng, "batch_frontier", defaults.batch_frontier)?,
            hedge: bool_or(eng, "hedge", false)?,
            hedge_threshold: num_or(eng, "hedge_threshold", defaults.hedge_threshold)?,
            n_max: count_or(eng, "n_max", defaults.n_max)?,
            record_trace: bool_or(eng, "record_trace", defaults.record_trace)?,
            cache,
            observe,
            faults,
            resilience,
        };
        let spec = ScenarioSpec { name, seed, topology, workload, engine };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a spec from JSON text.
    pub fn parse(text: &str) -> anyhow::Result<ScenarioSpec> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("scenario json: {e}"))?;
        ScenarioSpec::from_json(&j)
    }

    /// Load a spec from a `.json` file.
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<ScenarioSpec> {
        ScenarioSpec::from_json(&Json::parse_file(path)?)
    }

    /// Canonical pretty-printed JSON (sorted keys, trailing newline) —
    /// what the shipped `scenarios/*.json` files contain.
    pub fn render(&self) -> String {
        let mut s = self.to_json().to_string_pretty();
        s.push('\n');
        s
    }

    // ------------------------------------------------------------------
    // Validation + resolution.
    // ------------------------------------------------------------------

    /// Check every numeric knob against the engine's domain: finite and
    /// in range. Runs at both construction boundaries — [`from_json`]
    /// (file/CLI specs) and [`build`] (natively constructed specs, e.g.
    /// the fuzz generator) — so no invalid spec reaches the kernel.
    ///
    /// Rejecting non-finite values also protects the serialization
    /// contract: `render()` emits non-finite numbers as JSON `null`, so
    /// a spec carrying an infinite cap or threshold would re-parse as a
    /// *different* spec, breaking the parse-render fixpoint. (JSON text
    /// like `1e400` overflows to f64 infinity at parse time, which is
    /// exactly how such values used to sneak in.)
    ///
    /// [`from_json`]: ScenarioSpec::from_json
    /// [`build`]: ScenarioSpec::build
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.topology.tenants.is_empty(),
            "scenario needs at least one tenant"
        );
        for t in &self.topology.tenants {
            if let Some(cap) = t.k_cap {
                anyhow::ensure!(
                    cap.is_finite() && cap >= 0.0,
                    "tenant '{}' k_cap must be a finite non-negative dollar amount \
                     (use null for unlimited), got {cap}",
                    t.name
                );
            }
            if let Some(p) = &t.policy {
                validate_policy(p)
                    .map_err(|e| anyhow::anyhow!("tenant '{}' policy: {e}", t.name))?;
            }
        }
        if let Some(cap) = self.topology.global_k_cap {
            anyhow::ensure!(
                cap.is_finite() && cap >= 0.0,
                "global_k_cap must be a finite non-negative dollar amount \
                 (use null for unlimited), got {cap}"
            );
        }
        anyhow::ensure!(
            self.topology.shards >= 1,
            "topology needs at least one shard ('shards' >= 1)"
        );
        anyhow::ensure!(
            self.workload.n >= 1,
            "workload must contain at least one query ('n' >= 1)"
        );
        match &self.workload.arrival {
            ArrivalProcess::Poisson { rate } => anyhow::ensure!(
                rate.is_finite() && *rate > 0.0,
                "poisson rate must be a finite positive arrival rate, got {rate}"
            ),
            ArrivalProcess::Periodic { gap } => anyhow::ensure!(
                gap.is_finite() && *gap >= 0.0,
                "periodic gap must be a finite non-negative interval, got {gap}"
            ),
            ArrivalProcess::Trace(times) => {
                for &t in times {
                    anyhow::ensure!(
                        t.is_finite() && t >= 0.0,
                        "trace arrival offsets must be finite and non-negative, got {t}"
                    );
                }
            }
        }
        if let Some(z) = &self.workload.zipf {
            anyhow::ensure!(
                z.exponent.is_finite() && z.exponent >= 0.0,
                "zipf exponent must be finite and non-negative, got {}",
                z.exponent
            );
            anyhow::ensure!(z.distinct >= 1, "zipf distinct must be at least 1");
        }
        validate_policy(&self.engine.policy)
            .map_err(|e| anyhow::anyhow!("engine policy: {e}"))?;
        // Checked even with hedging off: the knob still serializes, and a
        // non-finite value would break the render fixpoint regardless.
        anyhow::ensure!(
            self.engine.hedge_threshold.is_finite() && self.engine.hedge_threshold >= 0.0,
            "hedge_threshold must be a finite non-negative utility cutoff, got {}",
            self.engine.hedge_threshold
        );
        anyhow::ensure!(self.engine.n_max >= 1, "n_max must be at least 1");
        if let Some(o) = &self.engine.observe {
            anyhow::ensure!(
                o.metrics_interval.is_finite() && o.metrics_interval > 0.0,
                "observe.metrics_interval must be a finite positive number of \
                 virtual seconds, got {}",
                o.metrics_interval
            );
        }
        if let Some(f) = &self.engine.faults {
            for (name, p) in [
                ("edge_fail_p", f.edge_fail_p),
                ("cloud_fail_p", f.cloud_fail_p),
                ("straggler_p", f.straggler_p),
            ] {
                anyhow::ensure!(
                    p.is_finite() && (0.0..=1.0).contains(&p),
                    "faults.{name} must be a probability in [0, 1], got {p}"
                );
            }
            anyhow::ensure!(
                f.straggler_mult.is_finite() && f.straggler_mult >= 1.0,
                "faults.straggler_mult must be a finite latency multiplier >= 1, got {}",
                f.straggler_mult
            );
            for w in &f.outages {
                anyhow::ensure!(
                    w.start.is_finite() && w.end.is_finite() && w.start >= 0.0 && w.start <= w.end,
                    "faults outage window must satisfy 0 <= start <= end with finite \
                     bounds, got [{}, {})",
                    w.start,
                    w.end
                );
            }
        }
        if let Some(r) = &self.engine.resilience {
            if let Some(t) = r.timeout {
                anyhow::ensure!(
                    t.is_finite() && t > 0.0,
                    "resilience.timeout must be a finite positive number of virtual \
                     seconds (use null for no timeout), got {t}"
                );
            }
            anyhow::ensure!(
                r.max_retries <= 64,
                "resilience.max_retries must be at most 64, got {}",
                r.max_retries
            );
            anyhow::ensure!(
                r.backoff_base.is_finite() && r.backoff_base >= 0.0,
                "resilience.backoff_base must be finite and non-negative, got {}",
                r.backoff_base
            );
            anyhow::ensure!(
                r.backoff_jitter.is_finite() && (0.0..=1.0).contains(&r.backoff_jitter),
                "resilience.backoff_jitter must be in [0, 1], got {}",
                r.backoff_jitter
            );
            anyhow::ensure!(
                r.failover_after <= 64,
                "resilience.failover_after must be at most 64 (0 disables failover), got {}",
                r.failover_after
            );
        }
        Ok(())
    }

    /// Resolve the declarative spec into a runnable [`Session`] over the
    /// paper-calibrated simulation substrate, injecting the utility
    /// predictor (trained mirror, PJRT service, or synthetic fallback).
    /// Fails if the spec does not pass [`ScenarioSpec::validate`].
    pub fn build(&self, predictor: Arc<dyn UtilityPredictor>) -> anyhow::Result<Session> {
        self.validate()?;
        let sp = SimParams::default();
        let pipeline = build_pipeline(self, Arc::clone(&predictor));
        let tenants: Vec<TenantPool> = self
            .topology
            .tenants
            .iter()
            .map(|t| TenantPool::new(&t.name, t.k_cap.unwrap_or(f64::INFINITY)))
            .collect();
        let fleet = FleetConfig {
            admission_limit: self.topology.admission_limit,
            global_k_cap: self.topology.global_k_cap.unwrap_or(f64::INFINITY),
            record_trace: self.engine.record_trace,
            tenant_policies: self
                .topology
                .tenants
                .iter()
                .map(|t| t.policy.as_ref().map(|p| p.build(&sp)))
                .collect(),
            observe: self.engine.observe.clone(),
            faults: self.engine.faults.clone(),
            resilience: self.engine.resilience.clone(),
        };
        Ok(Session { spec: self.clone(), pipeline, tenants, fleet, predictor })
    }
}

/// Assemble the pipeline a spec describes. Factored out of
/// [`ScenarioSpec::build`] so sharded runs can stamp out one identical,
/// independent pipeline (own cache, own router state) per shard.
fn build_pipeline(spec: &ScenarioSpec, predictor: Arc<dyn UtilityPredictor>) -> HybridFlowPipeline {
    let sp = SimParams::default();
    let mut pcfg = PipelineConfig::paper_default(&sp);
    pcfg.policy = spec.engine.policy.build(&sp);
    pcfg.n_max = spec.engine.n_max;
    pcfg.schedule.chain_mode = spec.engine.chain_mode;
    pcfg.schedule.batch_frontier = spec.engine.batch_frontier;
    pcfg.schedule.hedge = spec.engine.hedge;
    pcfg.schedule.hedge_threshold = spec.engine.hedge_threshold;
    pcfg.schedule.edge_workers = spec.topology.edge_workers;
    pcfg.schedule.cloud_workers = spec.topology.cloud_workers;
    if let Some(c) = &spec.engine.cache {
        if c.capacity > 0 {
            let cache = SubtaskCache::new(c.capacity, c.policy);
            let cache = if c.shared_tier { cache.with_shared_tier() } else { cache };
            pcfg.schedule.cache = Some(Arc::new(cache));
        }
    }
    HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        predictor,
        pcfg,
    )
}

/// Numeric-parameter policies carry values that must stay in domain.
fn validate_policy(p: &PolicySpec) -> anyhow::Result<()> {
    match p {
        PolicySpec::Random(pr) => anyhow::ensure!(
            pr.is_finite() && (0.0..=1.0).contains(pr),
            "random offload probability must be in [0, 1], got {pr}"
        ),
        PolicySpec::Fixed(t) => anyhow::ensure!(
            t.is_finite(),
            "fixed threshold must be finite, got {t}"
        ),
        _ => {}
    }
    Ok(())
}

fn missing(field: &str) -> anyhow::Error {
    anyhow::anyhow!("scenario spec missing '{field}'")
}

fn req_num(j: &Json, k: &str) -> anyhow::Result<f64> {
    j.get(k).and_then(Json::as_f64).ok_or_else(|| missing(k))
}

/// Non-negative integer field, via the strict [`Json::as_integer`]
/// accessor. Negative, fractional, or non-finite values are schema
/// errors — a bare `as usize` cast would saturate `-1` to 0 (silently
/// flipping semantics, e.g. `admission_limit: -1` reading as
/// *unlimited*), truncate `6.7` to 6 (silently running a different
/// experiment than written), and read `1e400` (f64 infinity after JSON
/// parse) as a huge count.
fn req_count(j: &Json, k: &str) -> anyhow::Result<usize> {
    let v = req_num(j, k)?;
    let i = j
        .get(k)
        .and_then(Json::as_integer)
        .filter(|&i| i >= 0)
        .ok_or_else(|| anyhow::anyhow!("'{k}' must be a non-negative integer, got {v}"))?;
    Ok(i as usize)
}

fn count_or(j: &Json, k: &str, default: usize) -> anyhow::Result<usize> {
    match j.get(k) {
        None | Some(Json::Null) => Ok(default),
        Some(_) => req_count(j, k),
    }
}

fn req_str<'a>(j: &'a Json, k: &str) -> anyhow::Result<&'a str> {
    j.get(k).and_then(Json::as_str).ok_or_else(|| missing(k))
}

fn num_or(j: &Json, k: &str, default: f64) -> anyhow::Result<f64> {
    match j.get(k) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_f64().ok_or_else(|| anyhow::anyhow!("'{k}' must be a number")),
    }
}

fn bool_or(j: &Json, k: &str, default: bool) -> anyhow::Result<bool> {
    match j.get(k) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_bool().ok_or_else(|| anyhow::anyhow!("'{k}' must be a boolean")),
    }
}

/// `None` ⇄ JSON `null` (unlimited caps).
fn opt_num(v: Option<f64>) -> Json {
    v.map_or(Json::Null, Json::Num)
}

/// Optional dollar cap: `null`/absent = unlimited; negative caps are
/// schema errors (they would silently read as "already exhausted").
fn opt_num_field(j: &Json, k: &str) -> anyhow::Result<Option<f64>> {
    match j.get(k) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => {
            let v = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("'{k}' must be a number or null"))?;
            anyhow::ensure!(v >= 0.0, "'{k}' must be non-negative, got {v}");
            Ok(Some(v))
        }
    }
}

/// A resolved, runnable scenario: the assembled pipeline, tenant pools,
/// and fleet configuration. [`Session::run`] executes the workload on the
/// unified kernel; each run starts from cold tenant pools (and a cold
/// cache), so repeated runs reproduce the event trace byte-for-byte.
pub struct Session {
    pub spec: ScenarioSpec,
    pub pipeline: HybridFlowPipeline,
    pub tenants: Vec<TenantPool>,
    pub fleet: FleetConfig,
    /// Retained so sharded runs can build fresh per-shard pipelines that
    /// share the predictor but nothing mutable.
    predictor: Arc<dyn UtilityPredictor>,
}

impl Session {
    /// Execute the scenario end-to-end and return the kernel's report.
    ///
    /// Specs with `topology.shards > 1` fan out across one OS thread per
    /// shard (capped at the machine's parallelism); the report and trace
    /// bytes are independent of the thread count.
    pub fn run(&self) -> Report {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        self.run_with_threads(threads)
    }

    /// [`Session::run`] with an explicit worker-thread budget for the
    /// shard fan-out. `shards = 1` specs take the unsharded kernel path
    /// regardless of `threads`, preserving the golden-trace bytes.
    pub fn run_with_threads(&self, threads: usize) -> Report {
        if self.spec.topology.shards <= 1 {
            let arrivals = self.spec.workload.arrivals(self.tenants.len(), self.spec.seed);
            run_fleet(&self.pipeline, &self.fleet, self.tenants.clone(), arrivals, self.spec.seed)
        } else {
            self.run_sharded(self.spec.topology.shards, threads)
        }
    }

    /// Run the scenario's workload across `shards` independent kernel
    /// shards (see [`crate::sim::run_fleet_sharded`]), overriding the
    /// spec's own `topology.shards`. Used by the CLI `--shards` flag and
    /// the fuzz harness's shard/serial identity invariant.
    pub fn run_sharded(&self, shards: usize, threads: usize) -> Report {
        let arrivals = self.spec.workload.arrivals(self.tenants.len(), self.spec.seed);
        let spec = self.spec.clone();
        let predictor = Arc::clone(&self.predictor);
        let make_pipeline = move || build_pipeline(&spec, Arc::clone(&predictor));
        run_fleet_sharded(
            make_pipeline,
            &self.fleet,
            self.tenants.clone(),
            arrivals,
            self.spec.seed,
            shards,
            threads,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::MirrorPredictor;
    use crate::server::{serve_fleet, serve_fleet_zipf};

    fn predictor() -> Arc<MirrorPredictor> {
        Arc::new(MirrorPredictor::synthetic_for_tests())
    }

    fn small_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "unit".into(),
            seed: 7,
            topology: TopologySpec {
                edge_workers: 2,
                cloud_workers: 4,
                admission_limit: 0,
                global_k_cap: None,
                shards: 1,
                tenants: vec![
                    TenantSpec::unlimited("a"),
                    TenantSpec::capped("b", 0.01).with_policy(PolicySpec::AllEdge),
                ],
            },
            workload: WorkloadSpec {
                benchmark: Benchmark::Gpqa,
                n: 6,
                arrival: ArrivalProcess::Periodic { gap: 2.0 },
                zipf: None,
            },
            engine: EngineSpec::default(),
        }
    }

    #[test]
    fn policy_spec_roundtrip() {
        let cases = [
            PolicySpec::HybridFlow,
            PolicySpec::HybridFlowEq27,
            PolicySpec::HybridFlowCalibrated,
            PolicySpec::AllEdge,
            PolicySpec::AllCloud,
            PolicySpec::Oracle,
            PolicySpec::Random(0.37),
            PolicySpec::Fixed(0.65),
        ];
        for p in cases {
            assert_eq!(PolicySpec::parse(&p.render()), Some(p.clone()), "{}", p.render());
        }
        assert!(PolicySpec::parse("random:1.5").is_none(), "probability out of range");
        assert!(PolicySpec::parse("bogus").is_none());
    }

    #[test]
    fn spec_json_roundtrip_is_fixpoint() {
        let spec = small_spec();
        let text = spec.render();
        let back = ScenarioSpec::parse(&text).expect("parse rendered spec");
        assert_eq!(back, spec, "value round trip");
        assert_eq!(back.render(), text, "render fixpoint");
    }

    #[test]
    fn spec_with_zipf_and_cache_roundtrips() {
        let mut spec = small_spec();
        spec.workload.zipf = Some(ZipfMix::new(1.1, 4));
        spec.engine.cache = Some(CacheSpec {
            capacity: 64,
            policy: CachePolicyKind::Ttl(120.0),
            shared_tier: true,
        });
        spec.topology.global_k_cap = Some(0.5);
        let back = ScenarioSpec::parse(&spec.render()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(ScenarioSpec::parse("not json").is_err());
        assert!(ScenarioSpec::parse("{}").is_err(), "missing fields");
        // Unknown policy string.
        let mut j = small_spec().to_json();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Obj(eng)) = o.get_mut("engine") {
                eng.insert("policy".into(), Json::Str("warp".into()));
            }
        }
        assert!(ScenarioSpec::from_json(&j).is_err());
        // Empty tenant list.
        let mut j = small_spec().to_json();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Obj(t)) = o.get_mut("topology") {
                t.insert("tenants".into(), Json::Arr(vec![]));
            }
        }
        assert!(ScenarioSpec::from_json(&j).is_err());
    }

    #[test]
    fn parse_rejects_negative_counts_and_caps() {
        // Negative integers must error, not saturate to 0 (a cast-to-0
        // admission_limit would silently mean *unlimited*); fractional
        // counts must error, not truncate to a different experiment.
        for bad in [-1.0, 6.7] {
            for (section, field) in [
                ("topology", "admission_limit"),
                ("topology", "edge_workers"),
                ("workload", "n"),
            ] {
                let mut j = small_spec().to_json();
                if let Json::Obj(o) = &mut j {
                    if let Some(Json::Obj(s)) = o.get_mut(section) {
                        s.insert(field.into(), Json::Num(bad));
                    }
                }
                let err = ScenarioSpec::from_json(&j).unwrap_err().to_string();
                assert!(err.contains(field), "{section}.{field}={bad}: {err}");
            }
        }
        // Negative dollar caps would read as "already exhausted".
        let mut j = small_spec().to_json();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Obj(t)) = o.get_mut("topology") {
                t.insert("global_k_cap".into(), Json::Num(-0.5));
            }
        }
        assert!(ScenarioSpec::from_json(&j).is_err());
    }

    #[test]
    fn validate_rejects_out_of_domain_knobs() {
        // Non-finite values slip past range checks like `rate > 0.0`
        // (infinity is "positive") and would render as JSON `null`,
        // breaking the parse-render fixpoint — the validator is the
        // single chokepoint for both the JSON and native build paths.
        assert!(small_spec().validate().is_ok());

        let mut s = small_spec();
        s.workload.arrival = ArrivalProcess::Poisson { rate: f64::INFINITY };
        assert!(s.validate().is_err(), "inf poisson rate");
        assert!(s.build(predictor()).is_err(), "build must validate too");

        let mut s = small_spec();
        s.workload.arrival = ArrivalProcess::Trace(vec![1.0, f64::NAN]);
        assert!(s.validate().is_err(), "NaN trace offset");

        let mut s = small_spec();
        s.workload.arrival = ArrivalProcess::Trace(vec![-2.0, 1.0]);
        assert!(s.validate().is_err(), "negative trace offset");

        let mut s = small_spec();
        s.workload.n = 0;
        assert!(s.validate().is_err(), "zero-query workload");

        let mut s = small_spec();
        s.workload.zipf = Some(ZipfMix::new(f64::INFINITY, 3));
        assert!(s.validate().is_err(), "inf zipf exponent");

        let mut s = small_spec();
        s.engine.hedge_threshold = f64::INFINITY;
        s.engine.hedge = false;
        assert!(s.validate().is_err(), "inf hedge_threshold rejected even with hedge off");

        let mut s = small_spec();
        s.topology.tenants[0].k_cap = Some(f64::INFINITY);
        assert!(s.validate().is_err(), "inf tenant cap (None is the unlimited spelling)");

        let mut s = small_spec();
        s.topology.global_k_cap = Some(f64::NAN);
        assert!(s.validate().is_err(), "NaN global cap");

        let mut s = small_spec();
        s.engine.policy = PolicySpec::Fixed(f64::NAN);
        assert!(s.validate().is_err(), "NaN fixed threshold");

        let mut s = small_spec();
        s.topology.tenants[1].policy = Some(PolicySpec::Random(f64::INFINITY));
        assert!(s.validate().is_err(), "inf random probability in tenant override");
    }

    #[test]
    fn parse_rejects_nonfinite_json_numbers() {
        // JSON text like `1e400` parses to f64 infinity (Rust's f64
        // parser overflows to inf, our Json layer keeps it); the
        // validator must stop it at the parse boundary.
        let with = |section: &str, field: &str, v: Json| {
            let mut j = small_spec().to_json();
            if let Json::Obj(o) = &mut j {
                if let Some(Json::Obj(s)) = o.get_mut(section) {
                    s.insert(field.into(), v);
                }
            }
            j
        };
        let mut j = small_spec().to_json();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Obj(wl)) = o.get_mut("workload") {
                if let Some(Json::Obj(arr)) = wl.get_mut("arrival") {
                    arr.insert("rate".into(), Json::Num(f64::INFINITY));
                    arr.insert("gap".into(), Json::Null);
                    arr.insert("process".into(), Json::Str("poisson".into()));
                }
            }
        }
        assert!(ScenarioSpec::from_json(&j).is_err(), "inf poisson rate via JSON");
        let j = with("engine", "hedge_threshold", Json::Num(f64::INFINITY));
        assert!(ScenarioSpec::from_json(&j).is_err(), "inf hedge_threshold via JSON");
        let j = with("topology", "global_k_cap", Json::Num(f64::INFINITY));
        assert!(ScenarioSpec::from_json(&j).is_err(), "inf global cap via JSON");
        // Non-finite counts fail the strict-integer accessor.
        let j = with("workload", "n", Json::Num(f64::INFINITY));
        let err = ScenarioSpec::from_json(&j).unwrap_err().to_string();
        assert!(err.contains('n'), "count error names the field: {err}");
    }

    #[test]
    fn session_run_is_deterministic() {
        let session = small_spec().build(predictor()).unwrap();
        let a = session.run();
        let b = session.run();
        assert_eq!(a.results.len(), 6);
        assert_eq!(a.trace_text(), b.trace_text(), "reruns must be byte-identical");
        // Tenant policy override held: the all-edge tenant never offloads.
        assert_eq!(a.tenants[1].state.n_offloaded, 0);
        assert!(a.tenants[1].state.n_decided > 0);
    }

    #[test]
    fn session_matches_serve_fleet_byte_for_byte() {
        // The scenario layer must reproduce the historical hand-wired
        // entrypoint exactly: same arrivals, same kernel, same trace.
        let spec = small_spec();
        let session = spec.build(predictor()).unwrap();
        let via_scenario = session.run();
        let via_server = serve_fleet(
            &session.pipeline,
            &session.fleet,
            session.tenants.clone(),
            spec.workload.benchmark,
            spec.workload.n,
            &spec.workload.arrival,
            spec.seed,
        );
        assert_eq!(via_scenario.trace_text(), via_server.trace_text());
        assert_eq!(via_scenario.total_api_cost, via_server.total_api_cost);
    }

    #[test]
    fn session_matches_serve_fleet_zipf_byte_for_byte() {
        let mut spec = small_spec();
        spec.workload.zipf = Some(ZipfMix::new(1.2, 3));
        spec.engine.cache =
            Some(CacheSpec { capacity: 128, policy: CachePolicyKind::Lru, shared_tier: true });
        let session = spec.build(predictor()).unwrap();
        let via_scenario = session.run();
        let via_server = serve_fleet_zipf(
            &session.pipeline,
            &session.fleet,
            session.tenants.clone(),
            spec.workload.benchmark,
            spec.workload.n,
            &spec.workload.arrival,
            spec.workload.zipf.as_ref().unwrap(),
            spec.seed,
        );
        assert_eq!(via_scenario.trace_text(), via_server.trace_text());
    }

    #[test]
    fn shards_field_roundtrips_and_defaults_to_one() {
        let mut spec = small_spec();
        spec.topology.shards = 4;
        let back = ScenarioSpec::parse(&spec.render()).unwrap();
        assert_eq!(back, spec, "shards survives the JSON round trip");
        assert_eq!(back.render(), spec.render(), "render fixpoint with shards");
        // Pre-sharding spec files carry no "shards" key: default is 1.
        let mut j = small_spec().to_json();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Obj(t)) = o.get_mut("topology") {
                t.remove("shards");
            }
        }
        let parsed = ScenarioSpec::from_json(&j).unwrap();
        assert_eq!(parsed.topology.shards, 1, "absent shards reads as the unsharded kernel");
    }

    #[test]
    fn validate_rejects_zero_shards() {
        let mut s = small_spec();
        s.topology.shards = 0;
        assert!(s.validate().is_err(), "zero shards is meaningless");
        let err = ScenarioSpec::parse(&{
            let mut j = small_spec().to_json();
            if let Json::Obj(o) = &mut j {
                if let Some(Json::Obj(t)) = o.get_mut("topology") {
                    t.insert("shards".into(), Json::Num(0.0));
                }
            }
            j.to_string_pretty()
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("shards"), "parse error names the field: {err}");
    }

    #[test]
    fn observe_block_roundtrips_and_defaults_to_none() {
        let mut spec = small_spec();
        spec.engine.observe =
            Some(ObserveConfig { spans: true, metrics: false, metrics_interval: 0.25 });
        let back = ScenarioSpec::parse(&spec.render()).unwrap();
        assert_eq!(back, spec, "observe survives the JSON round trip");
        assert_eq!(back.render(), spec.render(), "render fixpoint with observe");
        // Pre-observability spec files carry no "observe" key: fully off.
        let plain = small_spec();
        let parsed = ScenarioSpec::parse(&plain.render()).unwrap();
        assert!(parsed.engine.observe.is_none(), "absent observe reads as off");
        assert!(
            !plain.render().contains("observe"),
            "observe-off specs keep their pre-observability bytes"
        );
        // An explicit `null` is the same spelling as absent.
        let mut j = small_spec().to_json();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Obj(eng)) = o.get_mut("engine") {
                eng.insert("observe".into(), Json::Null);
            }
        }
        assert!(ScenarioSpec::from_json(&j).unwrap().engine.observe.is_none());
        // A bare `{}` block turns everything on at the default interval.
        let mut j = small_spec().to_json();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Obj(eng)) = o.get_mut("engine") {
                eng.insert("observe".into(), Json::obj(vec![]));
            }
        }
        assert_eq!(
            ScenarioSpec::from_json(&j).unwrap().engine.observe,
            Some(ObserveConfig::default())
        );
    }

    #[test]
    fn fault_blocks_roundtrip_and_default_to_none() {
        let mut spec = small_spec();
        spec.engine.faults = Some(FaultConfig {
            edge_fail_p: 0.05,
            cloud_fail_p: 0.2,
            straggler_p: 0.1,
            straggler_mult: 4.0,
            seed: 99,
            outages: vec![OutageWindow { cloud: true, start: 3.0, end: 8.0 }],
        });
        spec.engine.resilience = Some(ResilienceConfig {
            timeout: Some(12.0),
            max_retries: 4,
            backoff_base: 0.1,
            backoff_jitter: 0.25,
            failover_after: 1,
        });
        let back = ScenarioSpec::parse(&spec.render()).unwrap();
        assert_eq!(back, spec, "fault blocks survive the JSON round trip");
        assert_eq!(back.render(), spec.render(), "render fixpoint with faults");
        // Pre-fault spec files carry neither key: fully off.
        let plain = small_spec();
        let parsed = ScenarioSpec::parse(&plain.render()).unwrap();
        assert!(parsed.engine.faults.is_none() && parsed.engine.resilience.is_none());
        assert!(
            !plain.render().contains("faults") && !plain.render().contains("resilience"),
            "fault-off specs keep their pre-fault bytes"
        );
        // Bare `{}` blocks read as the defaults (no faults / default
        // resilience), and an explicit `null` is the same as absent.
        let mut j = small_spec().to_json();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Obj(eng)) = o.get_mut("engine") {
                eng.insert("faults".into(), Json::obj(vec![]));
                eng.insert("resilience".into(), Json::Null);
            }
        }
        let parsed = ScenarioSpec::from_json(&j).unwrap();
        assert_eq!(parsed.engine.faults, Some(FaultConfig::default()));
        assert!(parsed.engine.resilience.is_none());
    }

    #[test]
    fn validate_rejects_bad_fault_knobs() {
        let cases: Vec<(&str, Box<dyn Fn(&mut ScenarioSpec)>)> = vec![
            ("edge_fail_p", Box::new(|s| {
                s.engine.faults =
                    Some(FaultConfig { edge_fail_p: 1.5, ..FaultConfig::default() });
            })),
            ("cloud_fail_p", Box::new(|s| {
                s.engine.faults =
                    Some(FaultConfig { cloud_fail_p: f64::NAN, ..FaultConfig::default() });
            })),
            ("straggler_mult", Box::new(|s| {
                s.engine.faults =
                    Some(FaultConfig { straggler_mult: 0.5, ..FaultConfig::default() });
            })),
            ("outage", Box::new(|s| {
                s.engine.faults = Some(FaultConfig {
                    outages: vec![OutageWindow { cloud: false, start: 9.0, end: 3.0 }],
                    ..FaultConfig::default()
                });
            })),
            ("timeout", Box::new(|s| {
                s.engine.resilience =
                    Some(ResilienceConfig { timeout: Some(0.0), ..ResilienceConfig::default() });
            })),
            ("max_retries", Box::new(|s| {
                s.engine.resilience =
                    Some(ResilienceConfig { max_retries: 65, ..ResilienceConfig::default() });
            })),
            ("backoff_jitter", Box::new(|s| {
                s.engine.resilience =
                    Some(ResilienceConfig { backoff_jitter: 2.0, ..ResilienceConfig::default() });
            })),
            ("failover_after", Box::new(|s| {
                s.engine.resilience =
                    Some(ResilienceConfig { failover_after: 100, ..ResilienceConfig::default() });
            })),
        ];
        for (field, mutate) in cases {
            let mut s = small_spec();
            mutate(&mut s);
            let err = s.validate().unwrap_err().to_string();
            assert!(err.contains(field), "{field}: {err}");
        }
        // Unknown outage side is a parse error.
        let mut spec = small_spec();
        spec.engine.faults = Some(FaultConfig {
            outages: vec![OutageWindow { cloud: true, start: 0.0, end: 1.0 }],
            ..FaultConfig::default()
        });
        let mut j = spec.to_json();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Obj(eng)) = o.get_mut("engine") {
                if let Some(Json::Obj(f)) = eng.get_mut("faults") {
                    if let Some(Json::Arr(ws)) = f.get_mut("outages") {
                        if let Json::Obj(w) = &mut ws[0] {
                            w.insert("side".into(), Json::Str("moon".into()));
                        }
                    }
                }
            }
        }
        let err = ScenarioSpec::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("side"), "{err}");
    }

    #[test]
    fn silent_fault_layer_matches_plain_trace() {
        // A fault layer whose every probability is zero and whose outage
        // list is empty must reproduce the plain kernel's trace bytes:
        // the per-attempt draws come from forked streams, not the query
        // stream, so enabling the layer consumes no shared randomness.
        let plain = small_spec().build(predictor()).unwrap().run();
        let mut spec = small_spec();
        spec.engine.faults = Some(FaultConfig { seed: 42, ..FaultConfig::default() });
        spec.engine.resilience = Some(ResilienceConfig::default());
        let silent = spec.build(predictor()).unwrap().run();
        assert_eq!(plain.trace_text(), silent.trace_text(), "trace bytes unchanged");
        let stats = silent.faults.expect("fault layer reports stats");
        assert_eq!(stats.failures, 0);
        assert_eq!(stats.retries, 0);
        assert_eq!(stats.availability(), 1.0);
        assert!(stats.attempts > 0, "attempts counted under the layer");
        assert!(plain.faults.is_none(), "fault-off report carries no section");
    }

    #[test]
    fn validate_rejects_bad_metrics_interval() {
        for bad in [0.0, -1.0, f64::INFINITY, f64::NAN] {
            let mut s = small_spec();
            s.engine.observe = Some(ObserveConfig { metrics_interval: bad, ..Default::default() });
            let err = s.validate().unwrap_err().to_string();
            assert!(err.contains("metrics_interval"), "interval {bad}: {err}");
        }
    }

    #[test]
    fn observed_session_matches_unobserved_trace() {
        // Observability is read-only: turning it on must not perturb a
        // single kernel decision, and turning it off must leave no
        // artifact sections behind.
        let plain_session = small_spec().build(predictor()).unwrap();
        let plain = plain_session.run();
        assert!(plain.obs.is_none() && plain.critical_path.is_none());
        let mut spec = small_spec();
        spec.engine.observe = Some(ObserveConfig::default());
        let observed = spec.build(predictor()).unwrap().run();
        assert_eq!(plain.trace_text(), observed.trace_text(), "kernel decisions unchanged");
        let obs = observed.obs.expect("observed run carries artifacts");
        assert!(!obs.spans.is_empty(), "spans recorded");
        assert!(!obs.snapshots.is_empty(), "metrics sampled");
        assert_eq!(obs.unclosed_spans, 0, "every opened span closed");
        assert!(observed.critical_path.is_some(), "critical path surfaced");
        assert!(observed.render().contains("critical path:"));
    }

    #[test]
    fn sharded_session_is_thread_count_invariant() {
        let mut spec = small_spec();
        spec.workload.n = 24;
        spec.topology.shards = 3;
        let session = spec.build(predictor()).unwrap();
        let serial = session.run_with_threads(1);
        for threads in [2, 4, 8] {
            let parallel = session.run_with_threads(threads);
            assert_eq!(
                serial.trace_text(),
                parallel.trace_text(),
                "trace bytes at {threads} threads"
            );
            assert_eq!(
                serial.to_json().to_string_pretty(),
                parallel.to_json().to_string_pretty(),
                "report bytes at {threads} threads"
            );
        }
        assert_eq!(serial.results.len(), 24, "every query accounted for after the merge");
    }

    #[test]
    fn run_sharded_at_one_shard_matches_plain_run() {
        // The `--shards 1` override must land exactly on the unsharded
        // kernel's bytes — same contract the golden fleet trace pins.
        let session = small_spec().build(predictor()).unwrap();
        let plain = session.run();
        let sharded = session.run_sharded(1, 4);
        assert_eq!(plain.trace_text(), sharded.trace_text());
        assert_eq!(plain.to_json().to_string_pretty(), sharded.to_json().to_string_pretty());
    }
}

//! Declarative scenario sweeps: vary one or more [`ScenarioSpec`] fields
//! across value lists, fan the resulting session grid out over a
//! [`ThreadPool`], and tabulate the per-cell reports.
//!
//! This is the ROADMAP's `sweep` construct: experiments like
//! `fleet_serve`'s arrival-rate sweep and `fleet_cache`'s capacity sweep
//! used to run every grid cell serially inside hand-written experiment
//! code; a [`SweepSpec`] expresses the same grid as data (JSON-round-trip
//! like the scenario layer itself) and runs it in parallel.
//!
//! Determinism contract: every cell is an independent, fully-specified
//! [`ScenarioSpec`] (the seed is part of the spec, each session builds its
//! own cache, and tenant pools are cloned per run), so parallel execution
//! is **byte-identical** to running the same cells serially — thread
//! count and interleaving cannot leak into any cell's result. Pinned by
//! `rust/tests/scenario.rs`.
//!
//! JSON form (canonical render: sorted keys, pretty-printed, trailing
//! newline — same contract as [`ScenarioSpec::render`]):
//!
//! ```json
//! {
//!   "base": { ...scenario spec... },
//!   "name": "fleet_cache_sweep",
//!   "sweep": [ { "field": "cache_capacity", "values": [0, 16, 64, 256] } ]
//! }
//! ```
//!
//! A file with `base` + `sweep` keys is a sweep; the CLI's
//! `run --scenario` auto-detects it (see [`SweepSpec::is_sweep_json`]).

use super::{CacheSpec, Report, ScenarioSpec};
use crate::bench::Table;
use crate::cache::CachePolicyKind;
use crate::router::UtilityPredictor;
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use crate::workload::trace::ArrivalProcess;
use std::sync::Arc;

/// Guard against accidental grid explosions (axes multiply).
const MAX_CELLS: usize = 4096;

/// A sweepable scalar field of [`ScenarioSpec`]. The string forms are the
/// JSON `field` names (parse ⇄ render fixpoint, like [`super::PolicySpec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepField {
    /// `workload.arrival` as a Poisson process at the swept rate.
    ArrivalRate,
    /// `engine.cache.capacity`; 0 removes the cache (cache-off baseline
    /// cell). A base spec without a cache gets LRU + shared tier.
    CacheCapacity,
    EdgeWorkers,
    CloudWorkers,
    AdmissionLimit,
    /// `workload.n` (query count).
    QueryCount,
    Seed,
    HedgeThreshold,
    /// `workload.zipf.exponent` (requires a Zipf mix in the base spec).
    ZipfExponent,
    /// `topology.shards` (kernel shard count; must stay >= 1, enforced by
    /// per-cell validation).
    Shards,
}

impl SweepField {
    pub const ALL: [SweepField; 10] = [
        SweepField::ArrivalRate,
        SweepField::CacheCapacity,
        SweepField::EdgeWorkers,
        SweepField::CloudWorkers,
        SweepField::AdmissionLimit,
        SweepField::QueryCount,
        SweepField::Seed,
        SweepField::HedgeThreshold,
        SweepField::ZipfExponent,
        SweepField::Shards,
    ];

    pub fn render(&self) -> &'static str {
        match self {
            SweepField::ArrivalRate => "arrival_rate",
            SweepField::CacheCapacity => "cache_capacity",
            SweepField::EdgeWorkers => "edge_workers",
            SweepField::CloudWorkers => "cloud_workers",
            SweepField::AdmissionLimit => "admission_limit",
            SweepField::QueryCount => "n",
            SweepField::Seed => "seed",
            SweepField::HedgeThreshold => "hedge_threshold",
            SweepField::ZipfExponent => "zipf_exponent",
            SweepField::Shards => "shards",
        }
    }

    pub fn parse(s: &str) -> Option<SweepField> {
        let lower = s.trim().to_ascii_lowercase();
        SweepField::ALL.iter().copied().find(|f| f.render() == lower)
    }

    /// Non-negative integer value check shared by the count-like fields.
    fn as_count(self, v: f64) -> anyhow::Result<usize> {
        anyhow::ensure!(
            v >= 0.0 && v.fract() == 0.0,
            "sweep field '{}' needs a non-negative integer, got {v}",
            self.render()
        );
        Ok(v as usize)
    }

    /// Apply one swept value to a spec.
    pub fn apply(&self, spec: &mut ScenarioSpec, v: f64) -> anyhow::Result<()> {
        match self {
            SweepField::ArrivalRate => {
                anyhow::ensure!(v > 0.0 && v.is_finite(), "arrival_rate must be positive");
                spec.workload.arrival = ArrivalProcess::Poisson { rate: v };
            }
            SweepField::CacheCapacity => {
                let cap = self.as_count(v)?;
                if cap == 0 {
                    spec.engine.cache = None;
                } else {
                    let mut c = spec.engine.cache.clone().unwrap_or(CacheSpec {
                        capacity: cap,
                        policy: CachePolicyKind::Lru,
                        shared_tier: true,
                    });
                    c.capacity = cap;
                    spec.engine.cache = Some(c);
                }
            }
            SweepField::EdgeWorkers => spec.topology.edge_workers = self.as_count(v)?,
            SweepField::CloudWorkers => spec.topology.cloud_workers = self.as_count(v)?,
            SweepField::AdmissionLimit => spec.topology.admission_limit = self.as_count(v)?,
            SweepField::QueryCount => spec.workload.n = self.as_count(v)?,
            SweepField::Seed => spec.seed = self.as_count(v)? as u64,
            SweepField::HedgeThreshold => {
                anyhow::ensure!(
                    v.is_finite() && v >= 0.0,
                    "hedge_threshold must be a finite non-negative cutoff"
                );
                spec.engine.hedge_threshold = v;
            }
            SweepField::ZipfExponent => {
                anyhow::ensure!(v >= 0.0, "zipf_exponent must be non-negative");
                let z = spec.workload.zipf.as_mut().ok_or_else(|| {
                    anyhow::anyhow!("zipf_exponent sweep needs a zipf mix in the base spec")
                })?;
                z.exponent = v;
            }
            SweepField::Shards => {
                let n = self.as_count(v)?;
                anyhow::ensure!(n >= 1, "shards sweep needs at least one shard, got {v}");
                spec.topology.shards = n;
            }
        }
        Ok(())
    }
}

/// One sweep dimension: a field and the values it takes.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepAxis {
    pub field: SweepField,
    pub values: Vec<f64>,
}

/// A declarative sweep: a base scenario plus one or more axes. The cell
/// grid is the axes' cross product, first axis outermost (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    pub name: String,
    pub base: ScenarioSpec,
    pub axes: Vec<SweepAxis>,
}

/// One resolved grid cell: the axis values (aligned with
/// [`SweepSpec::axes`]) and the fully-specified per-cell scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepCell {
    pub values: Vec<f64>,
    pub spec: ScenarioSpec,
}

impl SweepSpec {
    // ------------------------------------------------------------------
    // JSON (de)serialization — util/json, serde-free.
    // ------------------------------------------------------------------

    /// Whether a parsed JSON document is a sweep spec (vs a plain
    /// scenario): both `base` and `sweep` keys present.
    pub fn is_sweep_json(j: &Json) -> bool {
        j.get("base").is_some() && j.get("sweep").is_some()
    }

    pub fn to_json(&self) -> Json {
        let axes: Vec<Json> = self
            .axes
            .iter()
            .map(|a| {
                Json::obj(vec![
                    ("field", Json::Str(a.field.render().into())),
                    ("values", Json::from_f64_slice(&a.values)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("base", self.base.to_json()),
            ("sweep", Json::Arr(axes)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SweepSpec> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("sweep spec missing 'name'"))?
            .to_string();
        let base = ScenarioSpec::from_json(
            j.get("base").ok_or_else(|| anyhow::anyhow!("sweep spec missing 'base'"))?,
        )?;
        let axes = j
            .get("sweep")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("sweep spec missing 'sweep' axis list"))?
            .iter()
            .map(|a| {
                let field_name = a
                    .get("field")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("sweep axis missing 'field'"))?;
                let field = SweepField::parse(field_name).ok_or_else(|| {
                    anyhow::anyhow!("unknown sweep field '{field_name}'")
                })?;
                let values = a
                    .get("values")
                    .and_then(Json::f64_array)
                    .ok_or_else(|| anyhow::anyhow!("sweep axis missing numeric 'values'"))?;
                anyhow::ensure!(!values.is_empty(), "sweep axis '{field_name}' has no values");
                Ok(SweepAxis { field, values })
            })
            .collect::<anyhow::Result<Vec<SweepAxis>>>()?;
        anyhow::ensure!(!axes.is_empty(), "sweep spec needs at least one axis");
        let spec = SweepSpec { name, base, axes };
        spec.cells()?; // validate every cell resolves
        Ok(spec)
    }

    pub fn parse(text: &str) -> anyhow::Result<SweepSpec> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("sweep json: {e}"))?;
        SweepSpec::from_json(&j)
    }

    pub fn from_file(path: &std::path::Path) -> anyhow::Result<SweepSpec> {
        SweepSpec::from_json(&Json::parse_file(path)?)
    }

    /// Canonical pretty-printed JSON (sorted keys, trailing newline).
    pub fn render(&self) -> String {
        let mut s = self.to_json().to_string_pretty();
        s.push('\n');
        s
    }

    // ------------------------------------------------------------------
    // Grid resolution + execution.
    // ------------------------------------------------------------------

    /// Materialize the cell grid: the cross product of all axes in
    /// row-major order (first axis outermost), each cell a fully-applied
    /// copy of the base spec. Per-cell seeds are deterministic because the
    /// seed is part of the spec (and itself sweepable via the `seed`
    /// axis).
    pub fn cells(&self) -> anyhow::Result<Vec<SweepCell>> {
        anyhow::ensure!(!self.axes.is_empty(), "sweep spec needs at least one axis");
        for a in &self.axes {
            anyhow::ensure!(
                !a.values.is_empty(),
                "sweep axis '{}' has no values",
                a.field.render()
            );
        }
        let total: usize = self.axes.iter().map(|a| a.values.len()).product();
        anyhow::ensure!(
            total <= MAX_CELLS,
            "sweep grid has {total} cells (limit {MAX_CELLS})"
        );
        let mut cells = Vec::with_capacity(total);
        let mut idx = vec![0usize; self.axes.len()];
        loop {
            let mut spec = self.base.clone();
            let mut values = Vec::with_capacity(self.axes.len());
            for (a, &i) in self.axes.iter().zip(&idx) {
                let v = a.values[i];
                a.field.apply(&mut spec, v)?;
                values.push(v);
            }
            cells.push(SweepCell { values, spec });
            // Odometer increment, last axis fastest.
            let mut k = self.axes.len();
            loop {
                if k == 0 {
                    return Ok(cells);
                }
                k -= 1;
                idx[k] += 1;
                if idx[k] < self.axes[k].values.len() {
                    break;
                }
                idx[k] = 0;
            }
        }
    }

    /// Run every cell and tabulate. `threads <= 1` runs the grid serially
    /// on the calling thread; otherwise cells fan out across a
    /// [`ThreadPool`]. Results are in grid order either way, and each
    /// cell's report is byte-identical across thread counts (see the
    /// module docs' determinism contract).
    pub fn run(
        &self,
        predictor: Arc<dyn UtilityPredictor>,
        threads: usize,
    ) -> anyhow::Result<SweepReport> {
        // Materialize the grid once; cell specs move into the jobs (no
        // re-clone per cell).
        let (values, specs): (Vec<Vec<f64>>, Vec<ScenarioSpec>) =
            self.cells()?.into_iter().map(|c| (c.values, c.spec)).unzip();
        // Validate the whole grid up front so a bad cell surfaces as an
        // error here, not a panic inside a worker thread.
        for spec in &specs {
            spec.validate()?;
        }
        let reports: Vec<Report> = if threads <= 1 {
            specs
                .into_iter()
                .map(|spec| spec.build(Arc::clone(&predictor)).expect("cell validated above").run())
                .collect()
        } else {
            let jobs: Vec<(ScenarioSpec, Arc<dyn UtilityPredictor>)> = specs
                .into_iter()
                .map(|spec| (spec, Arc::clone(&predictor)))
                .collect();
            ThreadPool::new(threads)
                .map(jobs, |(spec, pred)| spec.build(pred).expect("cell validated above").run())
        };
        Ok(SweepReport {
            name: self.name.clone(),
            fields: self.axes.iter().map(|a| a.field).collect(),
            cells: values
                .into_iter()
                .zip(reports)
                .map(|(values, report)| SweepCellResult { values, report })
                .collect(),
        })
    }
}

/// One executed grid cell: axis values + the kernel's report.
#[derive(Debug, Clone)]
pub struct SweepCellResult {
    pub values: Vec<f64>,
    pub report: Report,
}

/// Tabulated outcome of a sweep run.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub name: String,
    /// Axis fields, aligned with every cell's `values`.
    pub fields: Vec<SweepField>,
    /// Cells in grid order (first axis outermost).
    pub cells: Vec<SweepCellResult>,
}

impl SweepReport {
    /// Whether any cell ran with a result cache attached (adds the
    /// hit-rate column).
    fn any_cache(&self) -> bool {
        self.cells.iter().any(|c| c.report.cache.is_some())
    }

    /// Render the sweep as a metrics table: one row per cell, axis values
    /// first, then the headline serving metrics.
    pub fn table(&self) -> Table {
        let mut columns: Vec<String> =
            self.fields.iter().map(|f| f.render().to_string()).collect();
        let cached = self.any_cache();
        for m in [
            "Queries", "Sojourn p50 (s)", "Sojourn p95 (s)", "Sojourn p99 (s)",
            "Offload (%)", "C_API ($)", "Forced-edge", "Edge util (%)",
        ] {
            columns.push(m.into());
        }
        if cached {
            columns.push("Hit rate (%)".into());
        }
        let col_refs: Vec<&str> = columns.iter().map(String::as_str).collect();
        let mut t = Table::new(&format!("sweep: {}", self.name), &col_refs);
        for cell in &self.cells {
            let r = &cell.report;
            let mut row: Vec<String> = cell.values.iter().map(|v| format!("{v}")).collect();
            row.push(r.results.len().to_string());
            row.push(format!("{:.2}", r.sojourn.p50));
            row.push(format!("{:.2}", r.sojourn.p95));
            row.push(format!("{:.2}", r.sojourn.p99));
            row.push(format!("{:.1}", r.offload_rate * 100.0));
            row.push(format!("{:.4}", r.total_api_cost));
            row.push(r.forced_edge.to_string());
            row.push(format!("{:.1}", r.edge_utilization * 100.0));
            if cached {
                row.push(
                    r.cache
                        .as_ref()
                        .map_or("-".into(), |c| format!("{:.1}", c.hit_rate() * 100.0)),
                );
            }
            t.row(row);
        }
        t
    }

    /// Machine-readable sweep table (`util::json`): axis fields + one
    /// entry per cell with its values and the full report JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            (
                "fields",
                Json::Arr(
                    self.fields.iter().map(|f| Json::Str(f.render().into())).collect(),
                ),
            ),
            (
                "cells",
                Json::Arr(
                    self.cells
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("values", Json::from_f64_slice(&c.values)),
                                ("report", c.report.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::MirrorPredictor;
    use crate::scenario::{EngineSpec, TenantSpec, TopologySpec, WorkloadSpec};
    use crate::workload::Benchmark;

    fn base() -> ScenarioSpec {
        ScenarioSpec {
            name: "unit".into(),
            seed: 7,
            topology: TopologySpec {
                edge_workers: 2,
                cloud_workers: 4,
                admission_limit: 0,
                global_k_cap: None,
                shards: 1,
                tenants: vec![TenantSpec::unlimited("a")],
            },
            workload: WorkloadSpec {
                benchmark: Benchmark::Gpqa,
                n: 4,
                arrival: ArrivalProcess::Periodic { gap: 2.0 },
                zipf: None,
            },
            engine: EngineSpec { record_trace: false, ..Default::default() },
        }
    }

    #[test]
    fn field_names_roundtrip() {
        for f in SweepField::ALL {
            assert_eq!(SweepField::parse(f.render()), Some(f), "{}", f.render());
        }
        assert!(SweepField::parse("bogus").is_none());
    }

    #[test]
    fn cells_cross_product_row_major() {
        let sweep = SweepSpec {
            name: "grid".into(),
            base: base(),
            axes: vec![
                SweepAxis { field: SweepField::EdgeWorkers, values: vec![1.0, 2.0] },
                SweepAxis { field: SweepField::Seed, values: vec![5.0, 6.0, 7.0] },
            ],
        };
        let cells = sweep.cells().unwrap();
        assert_eq!(cells.len(), 6);
        // First axis outermost, last axis fastest.
        assert_eq!(cells[0].values, vec![1.0, 5.0]);
        assert_eq!(cells[1].values, vec![1.0, 6.0]);
        assert_eq!(cells[3].values, vec![2.0, 5.0]);
        assert_eq!(cells[3].spec.topology.edge_workers, 2);
        assert_eq!(cells[3].spec.seed, 5);
        // Base untouched.
        assert_eq!(sweep.base.topology.edge_workers, 2);
    }

    #[test]
    fn cache_capacity_zero_removes_cache() {
        let mut spec = base();
        spec.engine.cache = Some(CacheSpec {
            capacity: 256,
            policy: CachePolicyKind::Lfu,
            shared_tier: false,
        });
        SweepField::CacheCapacity.apply(&mut spec, 0.0).unwrap();
        assert!(spec.engine.cache.is_none(), "capacity 0 is the cache-off baseline");
        SweepField::CacheCapacity.apply(&mut spec, 64.0).unwrap();
        let c = spec.engine.cache.as_ref().unwrap();
        assert_eq!(c.capacity, 64);
        assert_eq!(c.policy, CachePolicyKind::Lru, "absent base cache defaults to LRU");
    }

    #[test]
    fn cache_capacity_preserves_base_policy() {
        let mut spec = base();
        spec.engine.cache = Some(CacheSpec {
            capacity: 256,
            policy: CachePolicyKind::Ttl(60.0),
            shared_tier: false,
        });
        SweepField::CacheCapacity.apply(&mut spec, 16.0).unwrap();
        let c = spec.engine.cache.as_ref().unwrap();
        assert_eq!(c.capacity, 16);
        assert_eq!(c.policy, CachePolicyKind::Ttl(60.0));
        assert!(!c.shared_tier);
    }

    #[test]
    fn rejects_bad_values_and_shapes() {
        let mut spec = base();
        assert!(SweepField::ArrivalRate.apply(&mut spec, 0.0).is_err());
        assert!(SweepField::EdgeWorkers.apply(&mut spec, 1.5).is_err());
        assert!(SweepField::EdgeWorkers.apply(&mut spec, -1.0).is_err());
        assert!(SweepField::Shards.apply(&mut spec, 0.0).is_err(), "zero shards");
        assert!(SweepField::Shards.apply(&mut spec, 2.5).is_err(), "fractional shards");
        SweepField::Shards.apply(&mut spec, 4.0).unwrap();
        assert_eq!(spec.topology.shards, 4);
        assert!(
            SweepField::ZipfExponent.apply(&mut spec, 1.1).is_err(),
            "no zipf mix in the base spec"
        );
        let empty = SweepSpec { name: "x".into(), base: base(), axes: vec![] };
        assert!(empty.cells().is_err());
        // A natively-built axis with no values errors instead of panicking.
        let hollow = SweepSpec {
            name: "x".into(),
            base: base(),
            axes: vec![SweepAxis { field: SweepField::ArrivalRate, values: vec![] }],
        };
        assert!(hollow.cells().is_err());
    }

    #[test]
    fn json_roundtrip_is_fixpoint() {
        let sweep = SweepSpec {
            name: "rt".into(),
            base: base(),
            axes: vec![
                SweepAxis { field: SweepField::ArrivalRate, values: vec![0.25, 0.5, 1.0] },
                SweepAxis { field: SweepField::CacheCapacity, values: vec![0.0, 64.0] },
            ],
        };
        let text = sweep.render();
        assert!(SweepSpec::is_sweep_json(&Json::parse(&text).unwrap()));
        assert!(!SweepSpec::is_sweep_json(&base().to_json()));
        let back = SweepSpec::parse(&text).expect("parse rendered sweep");
        assert_eq!(back, sweep, "value round trip");
        assert_eq!(back.render(), text, "render fixpoint");
    }

    #[test]
    fn serial_run_produces_grid_ordered_cells() {
        let sweep = SweepSpec {
            name: "serial".into(),
            base: base(),
            axes: vec![SweepAxis {
                field: SweepField::ArrivalRate,
                values: vec![0.5, 2.0],
            }],
        };
        let pred = Arc::new(MirrorPredictor::synthetic_for_tests());
        let report = sweep.run(pred, 1).unwrap();
        assert_eq!(report.cells.len(), 2);
        assert_eq!(report.cells[0].values, vec![0.5]);
        assert_eq!(report.cells[1].values, vec![2.0]);
        for c in &report.cells {
            assert_eq!(c.report.results.len(), 4);
        }
        let table = report.table().render();
        assert!(table.contains("sweep: serial"), "{table}");
        assert!(table.contains("arrival_rate"), "{table}");
    }
}

//! Deterministic fault injection + resilience policies.
//!
//! The kernel lives on a virtual clock, so failures must be *scheduled
//! randomness*, not wall-clock accidents: every fault draw comes from an
//! RNG stream forked from the global `(query, node, attempt)` index
//! ([`FaultModel::attempt_rng`]), exactly like the sharded kernel's
//! arrival forking. Realizations are therefore shard-invariant and
//! byte-reproducible across reruns and thread counts — the same query
//! sees the same transient failure on attempt 2 whether the fleet runs
//! unsharded, sharded, or on 16 threads.
//!
//! Three ingredient structs:
//! * [`FaultConfig`] — what goes wrong: per-side transient failure
//!   probability, scheduled outage windows on the virtual clock
//!   ([`OutageWindow`]), and straggler tail inflation (latency multiplier
//!   applied with probability `straggler_p`).
//! * [`ResilienceConfig`] — what the scheduler does about it: per-subtask
//!   timeout, bounded retries with exponential backoff + jitter,
//!   cross-side failover after `failover_after` same-side failures, and
//!   graceful degradation (retry budget exhausted ⇒ the attempt runs on
//!   edge with every fault check suppressed, so the DAG always drains).
//! * [`FaultModel`] — the pair the kernel threads through `run_group`,
//!   `Some` iff either block was configured (absent ⇒ the exact
//!   pre-feature code path).
//!
//! Billing semantics: a failed attempt bills the work actually performed
//! (a failed cloud call still costs its tokens and dollars); an
//! outage-window rejection performs no work and bills nothing; a timed-out
//! attempt bills in full at dispatch and refunds the unconsumed share
//! `(1 - timeout/latency)` through the existing cancel machinery.

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Golden-ratio multiplier shared with the kernel's per-query forking.
const PHI64: u64 = 0x9E3779B97f4A7C15;
/// Distinct odd mix constants for the node / attempt axes.
const NODE_MIX: u64 = 0xC2B2AE3D27D4EB4F;
const ATTEMPT_MIX: u64 = 0x165667B19E3779F9;

/// A scheduled outage on the virtual clock: every dispatch on the given
/// side with `start <= t < end` is rejected instantly (no work, no cost).
/// Zero-length windows (`start == end`) match nothing.
#[derive(Debug, Clone, PartialEq)]
pub struct OutageWindow {
    /// `true` = cloud side, `false` = edge side.
    pub cloud: bool,
    pub start: f64,
    pub end: f64,
}

/// What goes wrong (see module docs). All probabilities are per-attempt.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Transient failure probability of an edge attempt.
    pub edge_fail_p: f64,
    /// Transient failure probability of a cloud attempt.
    pub cloud_fail_p: f64,
    /// Probability an attempt is a straggler.
    pub straggler_p: f64,
    /// Latency multiplier applied to straggler attempts (>= 1).
    pub straggler_mult: f64,
    /// Base seed of the forked per-attempt fault streams.
    pub seed: u64,
    /// Scheduled outage windows on the virtual clock.
    pub outages: Vec<OutageWindow>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            edge_fail_p: 0.0,
            cloud_fail_p: 0.0,
            straggler_p: 0.0,
            straggler_mult: 1.0,
            seed: 0,
            outages: Vec::new(),
        }
    }
}

/// What the scheduler does about faults (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct ResilienceConfig {
    /// Per-subtask attempt deadline on the virtual clock (`None` = no
    /// timeout). An attempt whose service time exceeds it is cancelled at
    /// `start + timeout`, the worker released, the unconsumed cost share
    /// refunded.
    pub timeout: Option<f64>,
    /// Retry budget per subtask: after `max_retries` failed attempts the
    /// next attempt is the degraded completion (edge side, fault checks
    /// suppressed), so every DAG terminates.
    pub max_retries: usize,
    /// Backoff before retry k is `backoff_base * 2^min(k,10)` seconds ...
    pub backoff_base: f64,
    /// ... inflated by `1 + backoff_jitter * U` with `U ~ Uniform[0,1)`
    /// from the forked attempt stream.
    pub backoff_jitter: f64,
    /// After this many failures on one side, the next attempt reroutes to
    /// the other side (`0` disables failover). Failover onto the cloud
    /// side additionally requires spendable budget — otherwise the
    /// attempt degrades to edge instead of burning dollars.
    pub failover_after: usize,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig {
            timeout: None,
            max_retries: 3,
            backoff_base: 0.05,
            backoff_jitter: 0.1,
            failover_after: 2,
        }
    }
}

/// Per-attempt fault realization, drawn once per `(query, node, attempt)`
/// from the forked stream. The draw order (failure, straggler, backoff
/// jitter) is fixed so realizations never depend on which draws a caller
/// ends up using.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttemptDraws {
    /// Transient failure fired.
    pub failed: bool,
    /// Straggler inflation fired.
    pub straggler: bool,
    /// Backoff delay (seconds) before the *next* attempt, jitter applied.
    pub backoff: f64,
}

/// The fault + resilience pair the kernel threads through dispatch.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultModel {
    pub faults: FaultConfig,
    pub resilience: ResilienceConfig,
}

impl FaultModel {
    /// `Some` iff either block was configured; a missing half takes its
    /// defaults (no faults / default resilience).
    pub fn from_parts(
        faults: Option<FaultConfig>,
        resilience: Option<ResilienceConfig>,
    ) -> Option<FaultModel> {
        if faults.is_none() && resilience.is_none() {
            return None;
        }
        Some(FaultModel {
            faults: faults.unwrap_or_default(),
            resilience: resilience.unwrap_or_default(),
        })
    }

    /// Independent fault stream of one `(query, node, attempt)` cell. The
    /// query index is the *global* arrival index, so realizations are
    /// shard-invariant by construction.
    pub fn attempt_rng(&self, query: u64, node: u64, attempt: u64) -> Rng {
        Rng::new(
            self.faults.seed
                ^ query.wrapping_mul(PHI64)
                ^ node.wrapping_mul(NODE_MIX)
                ^ attempt.wrapping_mul(ATTEMPT_MIX),
        )
    }

    /// Fixed-order fault realization of one attempt (see [`AttemptDraws`]).
    pub fn draws(&self, query: u64, node: u64, attempt: u64, cloud: bool) -> AttemptDraws {
        let mut rng = self.attempt_rng(query, node, attempt);
        let p = if cloud { self.faults.cloud_fail_p } else { self.faults.edge_fail_p };
        let failed = rng.bernoulli(p);
        let straggler = rng.bernoulli(self.faults.straggler_p);
        let backoff = self.backoff(attempt, rng.f64());
        AttemptDraws { failed, straggler, backoff }
    }

    /// Whether side `cloud` is inside a scheduled outage at virtual time `t`.
    pub fn in_outage(&self, cloud: bool, t: f64) -> bool {
        self.faults.outages.iter().any(|w| w.cloud == cloud && t >= w.start && t < w.end)
    }

    /// Deterministic exponential backoff with jitter: `base * 2^min(k,10)
    /// * (1 + jitter * u)` where `u` comes from the forked attempt stream.
    pub fn backoff(&self, attempt: u64, u: f64) -> f64 {
        let pow = f64::from(1u32 << attempt.min(10) as u32);
        self.resilience.backoff_base * pow * (1.0 + self.resilience.backoff_jitter * u)
    }

    /// Attempts allowed before the degraded completion (retries + 1).
    pub fn max_attempts(&self) -> u32 {
        self.resilience.max_retries as u32 + 1
    }
}

/// Per-attempt fault annotation carried on trace events and spans.
/// `Default` (attempt 0, no flags) means "nothing fault-related happened",
/// and every renderer keeps such events byte-identical to the pre-fault
/// format — that is what pins faults-off (and fault-enabled-but-silent)
/// output to the golden bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultMark {
    /// 0-based attempt index of this dispatch.
    pub attempt: u32,
    /// Attempt failed transiently (work performed, result discarded).
    pub failed: bool,
    /// Attempt was rejected by an outage window (no work performed).
    pub outage: bool,
    /// Attempt was cancelled by the per-subtask timeout.
    pub timeout: bool,
    /// Attempt was rerouted to the other side by failover.
    pub failed_over: bool,
    /// Degraded completion (retry budget exhausted, forced edge).
    pub degraded: bool,
}

impl FaultMark {
    pub fn is_default(&self) -> bool {
        *self == FaultMark::default()
    }

    /// Trace-line suffix (leading space included), empty when default so
    /// unannotated lines keep their golden bytes.
    pub fn trace_suffix(&self) -> String {
        let mut s = String::new();
        if self.attempt > 0 {
            s.push_str(&format!(" attempt={}", self.attempt));
        }
        if self.failed_over {
            s.push_str(" failover=1");
        }
        if self.outage {
            s.push_str(" outage=1");
        }
        if self.failed {
            s.push_str(" failed=1");
        }
        if self.timeout {
            s.push_str(" timeout=1");
        }
        if self.degraded {
            s.push_str(" degraded=1");
        }
        s
    }
}

/// Roll-up of fault/resilience activity across a run (or one shard of
/// one; shards merge by summation).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultStats {
    /// Dispatch attempts made under the fault layer (cache hits excluded).
    pub attempts: usize,
    /// Transient + outage failures (timeouts counted separately).
    pub failures: usize,
    /// Attempts cancelled by the per-subtask timeout.
    pub timeouts: usize,
    /// Re-dispatches scheduled after a failed/timed-out attempt.
    pub retries: usize,
    /// Attempts rerouted to the other side by failover.
    pub failovers: usize,
    /// Queries that completed with at least one degraded subtask.
    pub degraded_queries: usize,
    /// Dollars refunded for the unconsumed share of timed-out attempts.
    pub refund: f64,
}

impl FaultStats {
    pub fn merge(&mut self, other: &FaultStats) {
        self.attempts += other.attempts;
        self.failures += other.failures;
        self.timeouts += other.timeouts;
        self.retries += other.retries;
        self.failovers += other.failovers;
        self.degraded_queries += other.degraded_queries;
        self.refund += other.refund;
    }

    /// Fraction of attempts that completed (neither failed nor timed out);
    /// 1.0 when no attempt ran under the fault layer.
    pub fn availability(&self) -> f64 {
        if self.attempts == 0 {
            1.0
        } else {
            (self.attempts - self.failures - self.timeouts) as f64 / self.attempts as f64
        }
    }

    pub fn render_line(&self) -> String {
        format!(
            "faults: {} attempts, {} failures, {} timeouts, {} retries, {} failovers, \
             {} degraded queries, ${:.4} refunded, availability {:.1}%",
            self.attempts,
            self.failures,
            self.timeouts,
            self.retries,
            self.failovers,
            self.degraded_queries,
            self.refund,
            100.0 * self.availability()
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("attempts", Json::Num(self.attempts as f64)),
            ("failures", Json::Num(self.failures as f64)),
            ("timeouts", Json::Num(self.timeouts as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("failovers", Json::Num(self.failovers as f64)),
            ("degraded_queries", Json::Num(self.degraded_queries as f64)),
            ("refund", Json::Num(self.refund)),
            ("availability", Json::Num(self.availability())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_parts_is_some_iff_either_block() {
        assert!(FaultModel::from_parts(None, None).is_none());
        let m = FaultModel::from_parts(Some(FaultConfig::default()), None).unwrap();
        assert_eq!(m.resilience, ResilienceConfig::default());
        let m = FaultModel::from_parts(None, Some(ResilienceConfig::default())).unwrap();
        assert_eq!(m.faults, FaultConfig::default());
    }

    #[test]
    fn attempt_streams_are_deterministic_and_independent() {
        let m = FaultModel::from_parts(Some(FaultConfig::default()), None).unwrap();
        let a: Vec<u64> = (0..4).map(|_| m.attempt_rng(3, 1, 0).next_u64()).collect();
        assert!(a.windows(2).all(|w| w[0] == w[1]), "same cell, same stream");
        // Any axis change moves the stream.
        assert_ne!(m.attempt_rng(3, 1, 0).next_u64(), m.attempt_rng(4, 1, 0).next_u64());
        assert_ne!(m.attempt_rng(3, 1, 0).next_u64(), m.attempt_rng(3, 2, 0).next_u64());
        assert_ne!(m.attempt_rng(3, 1, 0).next_u64(), m.attempt_rng(3, 1, 1).next_u64());
    }

    #[test]
    fn draws_respect_probability_extremes() {
        let cfg = FaultConfig {
            edge_fail_p: 0.0,
            cloud_fail_p: 1.0,
            straggler_p: 1.0,
            ..FaultConfig::default()
        };
        let m = FaultModel::from_parts(Some(cfg), None).unwrap();
        for q in 0..8 {
            let d = m.draws(q, 0, 0, true);
            assert!(d.failed && d.straggler, "p=1 always fires");
            let d = m.draws(q, 0, 0, false);
            assert!(!d.failed, "p=0 never fires");
        }
    }

    #[test]
    fn outage_windows_are_half_open_and_side_scoped() {
        let cfg = FaultConfig {
            outages: vec![
                OutageWindow { cloud: true, start: 10.0, end: 20.0 },
                OutageWindow { cloud: false, start: 5.0, end: 5.0 }, // zero-length
            ],
            ..FaultConfig::default()
        };
        let m = FaultModel::from_parts(Some(cfg), None).unwrap();
        assert!(m.in_outage(true, 10.0));
        assert!(m.in_outage(true, 19.999));
        assert!(!m.in_outage(true, 20.0), "end is exclusive");
        assert!(!m.in_outage(true, 9.999));
        assert!(!m.in_outage(false, 15.0), "edge side unaffected");
        assert!(!m.in_outage(false, 5.0), "zero-length window matches nothing");
    }

    #[test]
    fn backoff_doubles_then_caps_and_jitters() {
        let r = ResilienceConfig {
            backoff_base: 0.1,
            backoff_jitter: 0.5,
            ..ResilienceConfig::default()
        };
        let m = FaultModel::from_parts(None, Some(r)).unwrap();
        assert!((m.backoff(0, 0.0) - 0.1).abs() < 1e-12);
        assert!((m.backoff(3, 0.0) - 0.8).abs() < 1e-12);
        assert_eq!(m.backoff(10, 0.0), m.backoff(40, 0.0), "exponent caps at 10");
        assert!((m.backoff(0, 1.0) - 0.15).abs() < 1e-12, "jitter inflates by 1+j*u");
    }

    #[test]
    fn fault_mark_suffix_is_empty_when_default() {
        assert_eq!(FaultMark::default().trace_suffix(), "");
        assert!(FaultMark::default().is_default());
        let m = FaultMark { attempt: 2, failed: true, ..FaultMark::default() };
        assert_eq!(m.trace_suffix(), " attempt=2 failed=1");
        let m = FaultMark { timeout: true, degraded: true, ..FaultMark::default() };
        assert_eq!(m.trace_suffix(), " timeout=1 degraded=1");
    }

    #[test]
    fn stats_merge_and_availability() {
        let mut a = FaultStats {
            attempts: 10,
            failures: 2,
            timeouts: 1,
            retries: 3,
            failovers: 1,
            degraded_queries: 1,
            refund: 0.5,
        };
        let b = FaultStats { attempts: 5, failures: 1, ..FaultStats::default() };
        a.merge(&b);
        assert_eq!(a.attempts, 15);
        assert_eq!(a.failures, 3);
        assert!((a.availability() - 11.0 / 15.0).abs() < 1e-12);
        assert_eq!(FaultStats::default().availability(), 1.0);
        let line = a.render_line();
        assert!(line.starts_with("faults: 15 attempts"), "{line}");
        let j = a.to_json();
        assert_eq!(j.get("attempts").and_then(Json::as_i64), Some(15));
        assert_eq!(j.get("degraded_queries").and_then(Json::as_i64), Some(1));
    }
}

//! Cloud data-exposure proxy (paper App. D.1, Eqs. 29–31).
//!
//! The paper quantifies how much user-provided / intermediate information
//! each paradigm transmits to the cloud:
//!
//! * transmitted payload of an offloaded subtask: `x_i = (s_i, {a_j}_dep)`
//!   — the subtask prompt plus its dependency answers (never the full
//!   query);
//! * `E_cloud = sum_{i in C} tok(x_i)` (Eq. 30) — absolute token exposure;
//! * `E_bar = E_cloud / sum_{all i} tok(x_i)` (Eq. 31) — the fraction of
//!   subtask-level information the cloud observes.
//!
//! HybridFlow is *not* a privacy mechanism (the paper is explicit), but it
//! reduces the exposure **surface** relative to cloud-only inference; this
//! module measures that claim on the substrate.

use crate::scheduler::events::TraceEvent;

/// Exposure accounting for one query execution.
#[derive(Debug, Clone, Copy, Default)]
pub struct Exposure {
    /// Tokens transmitted to the cloud (Eq. 30).
    pub e_cloud: f64,
    /// Tokens processed on the edge.
    pub e_edge: f64,
    /// Cloud calls made.
    pub n_cloud_calls: usize,
}

impl Exposure {
    /// Compute from an execution trace: `tok(x_i)` is the call's input
    /// tokens (prompt + dependency answers), exactly the transmitted
    /// payload of Eq. 29.
    ///
    /// A *hedged* node transmitted its payload to the cloud regardless of
    /// which replica won (the speculative cloud call was dispatched and
    /// carried `x_i` before any cancellation), so exposure counts it as a
    /// cloud transmission even when `ev.cloud` records an edge winner.
    /// A *cached* node transmitted nothing anywhere — the stored result
    /// was served by the coordinator — so it contributes to neither side.
    pub fn from_events(events: &[TraceEvent]) -> Exposure {
        let mut e = Exposure::default();
        for ev in events {
            if ev.cached {
                continue;
            }
            if ev.cloud || ev.hedged {
                e.e_cloud += ev.in_tokens;
                e.n_cloud_calls += 1;
            } else {
                e.e_edge += ev.in_tokens;
            }
        }
        e
    }

    /// Normalized exposure `E_bar` (Eq. 31); 0 for edge-only, 1 for
    /// cloud-only, NaN when nothing executed.
    pub fn normalized(&self) -> f64 {
        self.e_cloud / (self.e_cloud + self.e_edge)
    }

    /// Cloud-only reference: everything (the full query, repeatedly)
    /// transmitted.
    pub fn merge(&mut self, other: &Exposure) {
        self.e_cloud += other.e_cloud;
        self.e_edge += other.e_edge;
        self.n_cloud_calls += other.n_cloud_calls;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cloud: bool, in_tokens: f64) -> TraceEvent {
        TraceEvent {
            node: 0,
            position: 0,
            cloud,
            tau: 0.0,
            u_hat: 0.0,
            start: 0.0,
            finish: 1.0,
            api_cost: 0.0,
            correct: true,
            in_tokens,
            hedged: false,
            cached: false,
            worker: 0,
            fault: crate::fault::FaultMark::default(),
        }
    }

    #[test]
    fn cached_events_transmit_nothing() {
        let mut hit = ev(true, 500.0);
        hit.cached = true;
        let e = Exposure::from_events(&[hit, ev(true, 100.0), ev(false, 50.0)]);
        assert_eq!(e.e_cloud, 100.0, "cached cloud-side hit is not a transmission");
        assert_eq!(e.e_edge, 50.0);
        assert_eq!(e.n_cloud_calls, 1);
    }

    #[test]
    fn accumulates_by_side() {
        let e = Exposure::from_events(&[ev(true, 100.0), ev(false, 50.0), ev(true, 30.0)]);
        assert_eq!(e.e_cloud, 130.0);
        assert_eq!(e.e_edge, 50.0);
        assert_eq!(e.n_cloud_calls, 2);
        assert!((e.normalized() - 130.0 / 180.0).abs() < 1e-12);
    }

    #[test]
    fn extremes() {
        let edge_only = Exposure::from_events(&[ev(false, 10.0), ev(false, 20.0)]);
        assert_eq!(edge_only.normalized(), 0.0);
        let cloud_only = Exposure::from_events(&[ev(true, 10.0)]);
        assert_eq!(cloud_only.normalized(), 1.0);
        let empty = Exposure::from_events(&[]);
        assert!(empty.normalized().is_nan());
    }

    #[test]
    fn merge_adds() {
        let mut a = Exposure::from_events(&[ev(true, 100.0)]);
        let b = Exposure::from_events(&[ev(false, 60.0), ev(true, 40.0)]);
        a.merge(&b);
        assert_eq!(a.e_cloud, 140.0);
        assert_eq!(a.e_edge, 60.0);
        assert_eq!(a.n_cloud_calls, 2);
    }
}

//! Metrics: the paper's three evaluation axes (Acc, C_time, C_API) plus the
//! unified utility of Table 3, aggregated per-seed as `mean ± std` exactly
//! like the paper's tables, and the App. D.1 cloud-exposure proxy.

pub mod exposure;

use crate::config::simparams::SimParams;
use crate::router::utility::{query_norm_cost, unified_utility};
use crate::util::stats::{fmt_mean_std, mean, std_pop};

/// Outcome of one query under one method.
#[derive(Debug, Clone, Copy)]
pub struct QueryOutcome {
    pub correct: bool,
    /// End-to-end `C_time` (s), planner included.
    pub latency: f64,
    /// Cloud `C_API` ($).
    pub api_cost: f64,
    /// Fraction of subtasks offloaded.
    pub offload_rate: f64,
    pub n_subtasks: usize,
}

/// Aggregate over one seed's query set.
#[derive(Debug, Clone, Copy)]
pub struct SeedStats {
    /// Accuracy in percent.
    pub acc: f64,
    /// Mean latency (s).
    pub time: f64,
    /// Mean API cost ($).
    pub api: f64,
    pub offload_rate: f64,
    pub mean_subtasks: f64,
}

impl SeedStats {
    pub fn from_outcomes(outcomes: &[QueryOutcome]) -> SeedStats {
        let n = outcomes.len().max(1) as f64;
        SeedStats {
            acc: outcomes.iter().filter(|o| o.correct).count() as f64 / n * 100.0,
            time: outcomes.iter().map(|o| o.latency).sum::<f64>() / n,
            api: outcomes.iter().map(|o| o.api_cost).sum::<f64>() / n,
            offload_rate: outcomes.iter().map(|o| o.offload_rate).sum::<f64>() / n,
            mean_subtasks: outcomes.iter().map(|o| o.n_subtasks as f64).sum::<f64>() / n,
        }
    }
}

/// `mean ± std` across seeds for each axis (the paper's table cells).
#[derive(Debug, Clone)]
pub struct MethodMetrics {
    pub acc_mean: f64,
    pub acc_std: f64,
    pub time_mean: f64,
    pub time_std: f64,
    pub api_mean: f64,
    pub offload_mean: f64,
    pub n_seeds: usize,
}

impl MethodMetrics {
    pub fn from_seeds(seeds: &[SeedStats]) -> MethodMetrics {
        let accs: Vec<f64> = seeds.iter().map(|s| s.acc).collect();
        let times: Vec<f64> = seeds.iter().map(|s| s.time).collect();
        let apis: Vec<f64> = seeds.iter().map(|s| s.api).collect();
        let off: Vec<f64> = seeds.iter().map(|s| s.offload_rate).collect();
        MethodMetrics {
            acc_mean: mean(&accs),
            acc_std: std_pop(&accs),
            time_mean: mean(&times),
            time_std: std_pop(&times),
            api_mean: mean(&apis),
            offload_mean: mean(&off),
            n_seeds: seeds.len(),
        }
    }

    /// Paper-style accuracy cell: "53.33±2.03".
    pub fn acc_cell(&self) -> String {
        fmt_mean_std(self.acc_mean, self.acc_std, 2)
    }

    /// Paper-style latency cell: "15.24±0.30".
    pub fn time_cell(&self) -> String {
        fmt_mean_std(self.time_mean, self.time_std, 2)
    }

    /// Paper-style API cell: "0.0075" (edge-only prints "-").
    pub fn api_cell(&self) -> String {
        if self.api_mean == 0.0 {
            "-".to_string()
        } else {
            format!("{:.4}", self.api_mean)
        }
    }

    /// Table 3 columns against an all-edge reference.
    pub fn norm_cost_and_utility(&self, sp: &SimParams, edge_ref: &MethodMetrics) -> (Option<f64>, Option<f64>) {
        if self.api_mean == 0.0 && self.time_mean <= edge_ref.time_mean {
            return (None, None);
        }
        let c = query_norm_cost(sp, self.time_mean, edge_ref.time_mean, self.api_mean);
        let u = unified_utility(
            sp,
            self.acc_mean,
            edge_ref.acc_mean,
            self.time_mean,
            edge_ref.time_mean,
            self.api_mean,
        );
        (Some(c), u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(correct: bool, latency: f64, api: f64) -> QueryOutcome {
        QueryOutcome { correct, latency, api_cost: api, offload_rate: 0.5, n_subtasks: 4 }
    }

    #[test]
    fn seed_stats_aggregate() {
        let o = vec![outcome(true, 10.0, 0.01), outcome(false, 20.0, 0.02)];
        let s = SeedStats::from_outcomes(&o);
        assert_eq!(s.acc, 50.0);
        assert_eq!(s.time, 15.0);
        assert!((s.api - 0.015).abs() < 1e-12);
        assert_eq!(s.mean_subtasks, 4.0);
    }

    #[test]
    fn method_metrics_mean_std() {
        let seeds = vec![
            SeedStats { acc: 50.0, time: 10.0, api: 0.01, offload_rate: 0.4, mean_subtasks: 4.0 },
            SeedStats { acc: 54.0, time: 12.0, api: 0.02, offload_rate: 0.5, mean_subtasks: 4.0 },
        ];
        let m = MethodMetrics::from_seeds(&seeds);
        assert_eq!(m.acc_mean, 52.0);
        assert_eq!(m.acc_std, 2.0);
        assert_eq!(m.acc_cell(), "52.00\u{b1}2.00");
        assert_eq!(m.time_cell(), "11.00\u{b1}1.00");
        assert_eq!(m.api_cell(), "0.0150");
    }

    #[test]
    fn api_cell_dash_for_edge_only() {
        let seeds =
            vec![SeedStats { acc: 25.0, time: 12.0, api: 0.0, offload_rate: 0.0, mean_subtasks: 1.0 }];
        assert_eq!(MethodMetrics::from_seeds(&seeds).api_cell(), "-");
    }

    #[test]
    fn table3_columns_match_paper_formula() {
        let sp = SimParams::default();
        let edge = MethodMetrics::from_seeds(&[SeedStats {
            acc: 25.54, time: 11.99, api: 0.0, offload_rate: 0.0, mean_subtasks: 5.0,
        }]);
        let hf = MethodMetrics::from_seeds(&[SeedStats {
            acc: 53.33, time: 15.24, api: 0.0075, offload_rate: 0.4, mean_subtasks: 5.0,
        }]);
        let (c, u) = hf.norm_cost_and_utility(&sp, &edge);
        assert!((c.unwrap() - 0.35).abs() < 0.005);
        assert!((u.unwrap() - 0.794).abs() < 0.01);
        let (c_e, u_e) = edge.norm_cost_and_utility(&sp, &edge);
        assert!(c_e.is_none() && u_e.is_none());
    }
}

//! Acceptance pins for the static-analysis suite (PR 9).
//!
//! * The committed tree is lint-clean (zero unexplained determinism
//!   hazards), and the `--json` report is byte-identical across reruns.
//! * Every seeded-bad fixture under `rust/tests/lint_fixtures/bad/`
//!   flags its namesake rule; the allow-annotated twins and the
//!   string/comment traps under `clean/` stay silent.
//! * Every shipped `scenarios/*.json` passes the feasibility checker
//!   (sweeps cell by cell); the overloaded corpus spec draws a
//!   stability error.
//! * The checker never panics on fuzz-generated specs, and a spec that
//!   checks without errors always `build()`s.

use hybridflow::analysis::lint::{lint_source, lint_tree};
use hybridflow::analysis::scenario::{check_spec, Severity};
use hybridflow::router::MirrorPredictor;
use hybridflow::scenario::{ScenarioSpec, SweepSpec};
use hybridflow::testing::fuzz::spec_for_case;
use hybridflow::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Recursive sorted `.rs` listing (mirrors the linter's traversal).
fn rs_files_under(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        for e in std::fs::read_dir(&d).expect("fixture dir") {
            let p = e.expect("fixture entry").path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    out
}

// -------------------------------------------------------------------------
// Lint: committed tree + fixture corpus.
// -------------------------------------------------------------------------

#[test]
fn committed_tree_is_lint_clean() {
    let report = lint_tree(&repo_root().join("rust/src")).expect("lint run");
    assert!(report.clean(), "determinism lint findings:\n{}", report.render());
    assert!(report.files > 40, "tree scan looks truncated: {} files", report.files);
}

#[test]
fn every_seeded_bad_fixture_flags_its_namesake_rule() {
    let dir = repo_root().join("rust/tests/lint_fixtures/bad");
    let files = rs_files_under(&dir);
    assert_eq!(files.len(), 7, "one seeded-bad fixture per rule: {files:?}");
    for path in files {
        let stem = path.file_stem().unwrap().to_string_lossy().to_string();
        let src = std::fs::read_to_string(&path).unwrap();
        let name = path.to_string_lossy().replace('\\', "/");
        let diags = lint_source(&name, &src);
        assert!(
            diags.iter().any(|d| d.rule == stem),
            "{name}: expected a '{stem}' finding, got {diags:?}"
        );
    }
}

#[test]
fn clean_fixtures_stay_silent() {
    let dir = repo_root().join("rust/tests/lint_fixtures/clean");
    let report = lint_tree(&dir).expect("lint run");
    assert!(report.clean(), "clean fixtures flagged:\n{}", report.render());
    assert_eq!(report.files, 4, "fixture set drifted");
}

#[test]
fn lint_json_report_is_byte_identical_across_reruns() {
    let root = repo_root().join("rust/src");
    let a = lint_tree(&root).expect("first run").json_text();
    let b = lint_tree(&root).expect("second run").json_text();
    assert_eq!(a, b, "lint --json must be byte-stable");
    let parsed = Json::parse(&a).expect("lint --json parses");
    assert!(parsed.get("files").is_some());
    assert!(parsed.get("findings").is_some());
}

// -------------------------------------------------------------------------
// Feasibility checker: shipped scenarios + corpus + fuzz coherence.
// -------------------------------------------------------------------------

#[test]
fn every_shipped_scenario_passes_the_checker() {
    let dir = repo_root().join("scenarios");
    let mut paths: Vec<PathBuf> =
        std::fs::read_dir(&dir).expect("scenarios dir").map(|e| e.unwrap().path()).collect();
    paths.sort();
    let mut checked = 0usize;
    for path in paths {
        if !path.extension().is_some_and(|x| x == "json") {
            continue;
        }
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        if SweepSpec::is_sweep_json(&j) {
            let sweep = SweepSpec::from_json(&j).expect("sweep parses");
            for cell in sweep.cells().expect("cells resolve") {
                let report = check_spec(&cell.spec);
                assert!(report.passed(), "{}:\n{}", path.display(), report.render());
            }
        } else {
            let spec = ScenarioSpec::from_json(&j).expect("scenario parses");
            let report = check_spec(&spec);
            assert!(report.passed(), "{}:\n{}", path.display(), report.render());
        }
        checked += 1;
    }
    assert!(checked >= 5, "expected the shipped scenario set, saw {checked}");
}

#[test]
fn overloaded_corpus_spec_draws_a_stability_error() {
    let path = repo_root().join("rust/tests/corpus/check_overloaded_pool.json");
    let spec = ScenarioSpec::parse(&std::fs::read_to_string(&path).unwrap()).expect("parses");
    let report = check_spec(&spec);
    assert!(report.load.rho_split >= 1.0, "not overloaded: {:?}", report.load);
    assert!(
        report
            .findings
            .iter()
            .any(|f| f.severity == Severity::Error && f.code == "stability"),
        "expected a stability error:\n{}",
        report.render()
    );
}

#[test]
fn checker_never_panics_and_passing_specs_build() {
    let predictor = Arc::new(MirrorPredictor::synthetic_for_tests());
    let mut passed = 0usize;
    for adversarial in [false, true] {
        for case in 0..128usize {
            let spec = spec_for_case(0xC0FFEE, case, adversarial);
            let report = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                check_spec(&spec)
            }))
            .unwrap_or_else(|_| panic!("check_spec panicked on case {case}\n{}", spec.render()));
            // Byte-stable rendering on arbitrary specs.
            assert_eq!(report.render(), check_spec(&spec).render(), "case {case}");
            if report.passed() {
                passed += 1;
                let pred: Arc<dyn hybridflow::router::UtilityPredictor> = predictor.clone();
                assert!(
                    spec.build(pred).is_ok(),
                    "case {case}: checker passed but build() rejected\n{}",
                    spec.render()
                );
            }
        }
    }
    assert!(passed > 0, "the generator never produced a checker-clean spec");
}

//! Scenario-API integration suite:
//!
//! * **round trip** — every shipped `scenarios/*.json` file parses, and
//!   `parse → render → parse` is a fixpoint (canonical serialization);
//! * **preset pinning** — the shipped files equal the canonical preset
//!   constructors, so the JSON on disk, the runnable examples, and the
//!   `eval` experiment tables can never drift apart;
//! * **kernel parity** — the golden fleet trace reproduces byte-for-byte
//!   through a scenario session, and a spec-driven run is byte-identical
//!   to the historical hand-wired `serve_fleet` construction.
//!
//! (The `fleet(N=1) == execute_query` decision-for-decision equivalence
//! and the single-query `--cache 0` bit-identity grid live in
//! `rust/tests/fleet.rs`; since the unification both sides of those
//! comparisons flow through `sim::Kernel`, pinning query-local vs
//! tenant-scoped budget modes against each other.)

use hybridflow::budget::TenantPool;
use hybridflow::cache::CachePolicyKind;
use hybridflow::config::simparams::SimParams;
use hybridflow::models::SimExecutor;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::router::{MirrorPredictor, RoutePolicy, UtilityPredictor};
use hybridflow::scenario::presets::{self, FleetCacheKnobs, FleetSimKnobs, MixedPolicyKnobs};
use hybridflow::scenario::{ScenarioSpec, SweepSpec};
use hybridflow::server::serve_fleet;
use hybridflow::sim::FleetConfig;
use hybridflow::util::json::Json;
use hybridflow::workload::trace::ArrivalProcess;
use hybridflow::workload::Benchmark;
use std::path::PathBuf;
use std::sync::Arc;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn shipped_specs() -> Vec<PathBuf> {
    ["fleet_sim", "fleet_mixed_policy", "fleet_cache", "fleet_sharded", "fleet_faulty"]
        .iter()
        .map(|name| repo_root().join("scenarios").join(format!("{name}.json")))
        .collect()
}

fn predictor() -> Arc<dyn UtilityPredictor> {
    Arc::new(MirrorPredictor::synthetic_for_tests())
}

// ---------------------------------------------------------------------------
// Round trip + preset pinning.
// ---------------------------------------------------------------------------

#[test]
fn shipped_specs_parse_and_roundtrip_fixpoint() {
    for path in shipped_specs() {
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
        let spec = ScenarioSpec::parse(&text)
            .unwrap_or_else(|e| panic!("parsing {}: {e}", path.display()));
        // parse → render → parse is the identity on the value...
        let rendered = spec.render();
        let back = ScenarioSpec::parse(&rendered)
            .unwrap_or_else(|e| panic!("reparsing render of {}: {e}", path.display()));
        assert_eq!(back, spec, "{}: value round trip", path.display());
        // ...and render is a fixpoint on canonical text.
        assert_eq!(back.render(), rendered, "{}: render fixpoint", path.display());
    }
}

#[test]
fn shipped_specs_match_their_presets() {
    let cases: Vec<(&str, ScenarioSpec)> = vec![
        (
            "fleet_sim",
            presets::fleet_sim(Benchmark::Gpqa, 60, 0.5, 11, &FleetSimKnobs::default()),
        ),
        (
            "fleet_mixed_policy",
            presets::mixed_policy(
                Benchmark::Gpqa,
                90,
                0.6,
                11,
                &MixedPolicyKnobs { hedge: true, record_trace: true, ..Default::default() },
            ),
        ),
        (
            "fleet_cache",
            presets::fleet_cache(
                Benchmark::Gpqa,
                120,
                0.5,
                11,
                &FleetCacheKnobs { zipf_distinct: 12, record_trace: true, ..Default::default() },
            ),
        ),
        ("fleet_sharded", presets::fleet_sharded(Benchmark::Gpqa, 240, 2.0, 11)),
        ("fleet_faulty", presets::fleet_faulty(Benchmark::Gpqa, 60, 0.5, 11)),
    ];
    for (name, preset) in cases {
        let path = repo_root().join("scenarios").join(format!("{name}.json"));
        let shipped = ScenarioSpec::from_file(&path).expect("shipped spec parses");
        assert_eq!(
            shipped, preset,
            "{name}.json drifted from scenario::presets::{name} — regenerate the file \
             with ScenarioSpec::render()"
        );
    }
}

// ---------------------------------------------------------------------------
// Sweep specs: shipped file, fixpoint, preset pin, thread invariance.
// ---------------------------------------------------------------------------

#[test]
fn shipped_sweep_spec_parses_roundtrips_and_matches_preset() {
    let path = repo_root().join("scenarios/fleet_cache_sweep.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    let parsed = Json::parse(&text).expect("sweep file is valid json");
    assert!(SweepSpec::is_sweep_json(&parsed), "base + sweep keys present");
    let sweep = SweepSpec::from_json(&parsed).expect("sweep file parses");

    // parse → render → parse is the identity, and render is a fixpoint.
    let rendered = sweep.render();
    let back = SweepSpec::parse(&rendered).expect("reparse rendered sweep");
    assert_eq!(back, sweep, "value round trip");
    assert_eq!(back.render(), rendered, "render fixpoint");

    // Pinned to the canonical preset (same knobs as the fleet_cache
    // experiment's capacity grid at paper scale).
    let preset = presets::fleet_cache_sweep(
        Benchmark::Gpqa,
        120,
        0.5,
        11,
        &FleetCacheKnobs { zipf_distinct: 12, record_trace: false, ..Default::default() },
    );
    assert_eq!(
        sweep, preset,
        "fleet_cache_sweep.json drifted from scenario::presets::fleet_cache_sweep — \
         regenerate the file with SweepSpec::render()"
    );
    // The grid is the documented capacity ladder with a cache-off baseline.
    let cells = sweep.cells().expect("grid resolves");
    assert_eq!(cells.len(), 4);
    assert!(cells[0].spec.engine.cache.is_none(), "capacity 0 cell is cache-off");
    assert_eq!(cells[3].spec.engine.cache.as_ref().unwrap().capacity, 256);
}

/// Acceptance pin: the `fleet_cache` capacity grid run across ThreadPool
/// workers is byte-identical, cell for cell, to serial execution — thread
/// count and interleaving cannot leak into any cell's result.
#[test]
fn sweep_parallel_is_byte_identical_to_serial() {
    // Small grid with traces on, so the comparison is the strongest one
    // the engine offers (the byte-stable event trace).
    let mut sweep = presets::fleet_cache_sweep(
        Benchmark::Gpqa,
        24,
        0.5,
        11,
        &FleetCacheKnobs { zipf_distinct: 4, record_trace: true, ..Default::default() },
    );
    sweep.axes[0].values = vec![0.0, 16.0, 64.0];

    let serial = sweep.run(predictor(), 1).expect("serial run");
    for threads in [2usize, 4, 8] {
        let parallel = sweep.run(predictor(), threads).expect("parallel run");
        assert_eq!(parallel.cells.len(), serial.cells.len());
        for (i, (p, s)) in parallel.cells.iter().zip(&serial.cells).enumerate() {
            assert_eq!(p.values, s.values, "cell {i} grid order");
            assert_eq!(
                p.report.trace_text(),
                s.report.trace_text(),
                "cell {i} trace must be byte-identical at {threads} threads"
            );
            assert_eq!(p.report.total_api_cost, s.report.total_api_cost, "cell {i}");
            assert_eq!(
                p.report.cache.as_ref().map(|c| (c.lookups, c.hits, c.evictions)),
                s.report.cache.as_ref().map(|c| (c.lookups, c.hits, c.evictions)),
                "cell {i} cache counters"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Report JSON: round trip through util::json.
// ---------------------------------------------------------------------------

#[test]
fn fleet_report_json_roundtrips_through_util_json() {
    let session = presets::fleet_cache(
        Benchmark::Gpqa,
        24,
        0.5,
        11,
        &FleetCacheKnobs { zipf_distinct: 4, record_trace: false, ..Default::default() },
    )
    .build(predictor())
    .expect("preset spec is valid");
    let report = session.run();
    let j = report.to_json();
    let text = j.to_string_pretty();
    let back = Json::parse(&text).expect("report json parses");
    assert_eq!(back, j, "pretty round trip is lossless");

    // Spot-check the plotting surface against the report.
    assert_eq!(back.get("n_queries").and_then(Json::as_usize), Some(report.results.len()));
    assert_eq!(
        back.get("total_api_cost").and_then(Json::as_f64),
        Some(report.total_api_cost)
    );
    assert_eq!(
        back.path(&["sojourn", "p95"]).and_then(Json::as_f64),
        Some(report.sojourn.p95)
    );
    assert_eq!(
        back.path(&["cache", "hits"]).and_then(Json::as_f64),
        Some(report.cache.as_ref().unwrap().hits as f64)
    );
    assert_eq!(
        back.path(&["tenants", "0", "name"]).and_then(Json::as_str),
        Some(report.tenants[0].name.as_str())
    );
    // Unlimited tenant caps serialize as null, not infinity.
    assert_eq!(back.path(&["tenants", "0", "k_cap"]), Some(&Json::Null));

    // The sweep table wraps the same report JSON per cell.
    let sweep = presets::fleet_serve_sweep(Benchmark::Gpqa, 12, 11);
    let sr = sweep.run(predictor(), 2).expect("sweep runs");
    let sj = sr.to_json();
    let sweep_back = Json::parse(&sj.to_string_pretty()).expect("sweep json parses");
    assert_eq!(sweep_back, sj);
    assert_eq!(
        sweep_back.path(&["fields", "0"]).and_then(Json::as_str),
        Some("arrival_rate")
    );
    assert_eq!(
        sweep_back.get("cells").and_then(Json::as_arr).map(<[Json]>::len),
        Some(5)
    );
    assert_eq!(
        sweep_back.path(&["cells", "0", "report", "n_queries"]).and_then(Json::as_usize),
        Some(12)
    );
}

// ---------------------------------------------------------------------------
// Kernel parity: golden trace + hand-wired equivalence.
// ---------------------------------------------------------------------------

/// The golden fleet workload expressed as a scenario must reproduce the
/// pinned trace (`rust/tests/golden/fleet_trace.txt`) byte-for-byte.
#[test]
fn golden_trace_reproduces_through_scenario_session() {
    let session = presets::golden_fleet().build(predictor()).expect("preset spec is valid");
    let first = session.run().trace_text();
    let second = session.run().trace_text();
    assert_eq!(first, second, "scenario session is not deterministic");
    assert!(first.lines().count() > 50, "golden workload too small to pin behavior");

    let path = repo_root().join("rust/tests/golden/fleet_trace.txt");
    if path.exists() {
        let pinned = std::fs::read_to_string(&path).expect("read golden file");
        assert_eq!(
            first,
            pinned,
            "scenario-driven golden trace diverged from {} — the Scenario API must be a \
             byte-identical veneer over the kernel",
            path.display()
        );
    } else {
        // The golden file self-bootstraps via rust/tests/fleet.rs; absent
        // (fresh checkout pre-bootstrap) the deterministic double-run
        // above still pins scenario-level reproducibility.
        eprintln!("[scenario golden] {} not bootstrapped yet; skipped", path.display());
    }
}

/// `shards = 1` is the unsharded kernel: the golden fleet pushed through
/// the sharded entry point (even on a multi-thread pool) must reproduce
/// the pinned golden trace byte-for-byte. This is the strongest parity
/// statement the repo can make — the sharded path earns its speedup by
/// partitioning, not by changing any per-query arithmetic.
#[test]
fn golden_trace_reproduces_through_sharded_path_at_one_shard() {
    let session = presets::golden_fleet().build(predictor()).expect("preset spec is valid");
    let sharded = session.run_sharded(1, 4).trace_text();
    let plain = session.run().trace_text();
    assert_eq!(sharded, plain, "run_sharded(1, _) must be byte-identical to the plain kernel");

    let path = repo_root().join("rust/tests/golden/fleet_trace.txt");
    if path.exists() {
        let pinned = std::fs::read_to_string(&path).expect("read golden file");
        assert_eq!(
            sharded,
            pinned,
            "sharded(1) golden trace diverged from {} — compared, never regenerated",
            path.display()
        );
    } else {
        eprintln!("[sharded golden] {} not bootstrapped yet; skipped", path.display());
    }
}

/// The shipped sharded scenario (4 shards, 240 queries) must produce a
/// report whose bytes do not depend on how many pool threads execute the
/// shards: 1, 2, 4, and 8 threads all merge to the same artifact.
#[test]
fn shipped_fleet_sharded_spec_is_thread_count_invariant() {
    let path = repo_root().join("scenarios/fleet_sharded.json");
    let spec = ScenarioSpec::from_file(&path).expect("shipped spec parses");
    assert_eq!(spec.topology.shards, 4, "shipped sharded spec pins 4 shards");
    let session = spec.build(predictor()).expect("shipped spec is valid");

    let serial = session.run_with_threads(1);
    assert_eq!(serial.results.len(), 240, "every query must survive the cross-shard merge");
    let serial_json = serial.to_json().to_string_pretty();
    for threads in [2usize, 4, 8] {
        let run = session.run_with_threads(threads);
        assert_eq!(
            run.to_json().to_string_pretty(),
            serial_json,
            "report bytes changed between 1 and {threads} threads"
        );
    }
}

/// A spec-driven session must be byte-identical to the historical
/// hand-wired construction of the same experiment (pipeline + tenants +
/// fleet config + serve_fleet), proving the declarative layer adds no
/// behavior of its own.
#[test]
fn shipped_mixed_policy_spec_matches_handwired_construction() {
    let path = repo_root().join("scenarios/fleet_mixed_policy.json");
    let spec = ScenarioSpec::from_file(&path).expect("shipped spec parses");
    let via_scenario = spec.build(predictor()).expect("shipped spec is valid").run();

    // Hand-wired: what PR 2/3 code had to write out by hand.
    let sp = SimParams::default();
    let mut pcfg = PipelineConfig::paper_default(&sp);
    pcfg.policy = RoutePolicy::hybridflow(&sp);
    pcfg.schedule.edge_workers = 4;
    pcfg.schedule.cloud_workers = 16;
    pcfg.schedule.hedge = true;
    pcfg.schedule.hedge_threshold = 0.55;
    let pipeline = HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        predictor(),
        pcfg,
    );
    let tenants = vec![
        TenantPool::unlimited("learned"),
        TenantPool::unlimited("fixed-0.65"),
        TenantPool::new("edge-pinned", 0.02),
    ];
    let cfg = FleetConfig {
        admission_limit: 64,
        record_trace: true,
        tenant_policies: vec![
            None,
            Some(RoutePolicy::FixedThreshold(0.65)),
            Some(RoutePolicy::AllEdge),
        ],
        ..Default::default()
    };
    let via_handwired = serve_fleet(
        &pipeline,
        &cfg,
        tenants,
        Benchmark::Gpqa,
        90,
        &ArrivalProcess::Poisson { rate: 0.6 },
        11,
    );

    assert_eq!(via_scenario.trace_text(), via_handwired.trace_text());
    assert_eq!(via_scenario.total_api_cost, via_handwired.total_api_cost);
    assert_eq!(via_scenario.hedge_cancelled, via_handwired.hedge_cancelled);
    for (a, b) in via_scenario.tenants.iter().zip(&via_handwired.tenants) {
        assert_eq!(a.state.k_used, b.state.k_used, "tenant {}", a.name);
        assert_eq!(a.state.n_offloaded, b.state.n_offloaded, "tenant {}", a.name);
    }
}

/// The shipped cached-Zipf scenario runs end-to-end, hits its cache, and
/// reruns byte-identically (the kernel resets the cache cold per run).
#[test]
fn shipped_fleet_cache_spec_runs_and_hits() {
    let path = repo_root().join("scenarios/fleet_cache.json");
    let spec = ScenarioSpec::from_file(&path).expect("shipped spec parses");
    assert_eq!(spec.engine.cache.as_ref().map(|c| c.policy), Some(CachePolicyKind::Lru));
    let session = spec.build(predictor()).expect("shipped spec is valid");
    let a = session.run();
    let b = session.run();
    assert_eq!(a.trace_text(), b.trace_text(), "cached scenario must be reproducible");
    let stats = a.cache.expect("cache stats present");
    assert!(stats.hits > 0, "Zipf repetition must produce cache hits");
    assert!(a.trace.iter().any(|l| l.contains("side=cache")), "cache hits visible in trace");
}

//! Artifact-dependent integration tests: require `make artifacts` to have
//! produced `artifacts/` (the Makefile's `test-rust` target guarantees it).
//!
//! Covers: python<->rust simparams drift, PJRT round trip, PJRT-vs-mirror
//! numeric parity, batched scoring consistency, edge-LM burn, and the full
//! pipeline + serving loop with the PJRT predictor on the request path.
//!
//! Gating: when `artifacts/*.hlo.txt` are absent these tests SKIP (with a
//! note) instead of failing hard, so a fresh checkout passes tier-1
//! without the python build step. Set `HYBRIDFLOW_ARTIFACTS=1` to turn a
//! missing artifact set into a hard failure (CI that runs `make artifacts`
//! first). PJRT-dependent tests additionally skip unless the crate was
//! built with `--features pjrt`.

use hybridflow::config::simparams::{verify_zoo_against_json, SimParams, FEAT_DIM};
use hybridflow::models::SimExecutor;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::router::predictor::UtilityPredictor;
use hybridflow::router::MirrorPredictor;
use hybridflow::runtime::RouterService;
use hybridflow::util::json::Json;
use hybridflow::util::rng::Rng;
use hybridflow::workload::{generate_queries, Benchmark};
use std::path::PathBuf;
use std::sync::Arc;

/// Locate artifacts, or `None` to skip the calling test. With
/// `HYBRIDFLOW_ARTIFACTS=1` a missing artifact set fails instead.
fn artifacts() -> Option<PathBuf> {
    let dir = hybridflow::config::default_artifacts_dir();
    if dir.join("router.hlo.txt").exists() {
        return Some(dir);
    }
    let required = std::env::var("HYBRIDFLOW_ARTIFACTS")
        .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
        .unwrap_or(false);
    assert!(
        !required,
        "HYBRIDFLOW_ARTIFACTS is set but artifacts are missing - run `make artifacts` \
         first (dir: {})",
        dir.display()
    );
    eprintln!(
        "[artifacts_integration] SKIP: artifacts absent (dir: {}); run `make artifacts` \
         or set HYBRIDFLOW_ARTIFACTS=1 to require them",
        dir.display()
    );
    None
}

/// PJRT tests additionally need the `pjrt` build feature (the default
/// offline build ships a stub engine).
fn pjrt_artifacts() -> Option<PathBuf> {
    let dir = artifacts()?;
    if cfg!(feature = "pjrt") {
        Some(dir)
    } else {
        eprintln!("[artifacts_integration] SKIP: built without `--features pjrt`");
        None
    }
}

fn rand_feats(n: usize, seed: u64) -> Vec<[f32; FEAT_DIM]> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut f = [0.0f32; FEAT_DIM];
            for v in f.iter_mut() {
                *v = rng.f64() as f32;
            }
            f
        })
        .collect()
}

#[test]
fn simparams_json_matches_rust_defaults() {
    let Some(dir) = artifacts() else { return };
    let sp = SimParams::load(&dir).expect("simparams drift between python and rust mirrors");
    assert_eq!(sp, SimParams::default());
    let j = Json::parse_file(&dir.join("simparams.json")).unwrap();
    verify_zoo_against_json(&j).expect("model/benchmark zoo drift");
}

#[test]
fn manifest_describes_all_artifacts() {
    let Some(dir) = artifacts() else { return };
    let manifest = Json::parse_file(&dir.join("manifest.json")).unwrap();
    let arts = manifest.get("artifacts").and_then(Json::as_obj).unwrap();
    for name in ["router.hlo.txt", "router_b1.hlo.txt", "router_b8.hlo.txt",
                 "router_b32.hlo.txt", "edge_lm.hlo.txt"] {
        assert!(arts.contains_key(name), "manifest missing {name}");
        assert!(dir.join(name).exists(), "artifact file missing {name}");
    }
    // Router input shapes match the compiled-in feature layout.
    let b8 = &arts["router_b8.hlo.txt"];
    let inputs = b8.get("inputs").and_then(Json::as_arr).unwrap();
    assert_eq!(inputs[0].f64_array().unwrap(), vec![8.0, FEAT_DIM as f64]);
    // Router val quality gate: the artifact ships with a usefully-trained net.
    let r2 = manifest.path(&["router_metrics", "val_r2"]).and_then(Json::as_f64).unwrap();
    assert!(r2 > 0.5, "router val R2 too low: {r2}");
}

#[test]
fn hlo_text_has_full_constants() {
    // Regression guard for the print_large_constants bug: the router HLO
    // must not contain elided constants, which the old parser reads as 0s.
    let Some(dir) = artifacts() else { return };
    for name in ["router_b1.hlo.txt", "edge_lm.hlo.txt"] {
        let text = std::fs::read_to_string(dir.join(name)).unwrap();
        assert!(
            !text.contains("constant({...})"),
            "{name} contains elided constants - weights would be stripped"
        );
    }
}

#[test]
fn pjrt_matches_mirror_numerically() {
    let Some(dir) = pjrt_artifacts() else { return };
    let svc = RouterService::start(&dir).expect("PJRT start");
    let mirror = MirrorPredictor::from_meta_file(&dir.join("router_meta.json")).unwrap();
    for (n, seed) in [(1usize, 1u64), (5, 2), (8, 3), (20, 4), (32, 5), (50, 6)] {
        let feats = rand_feats(n, seed);
        for c_used in [0.0, 0.4, 1.2] {
            let a = svc.score(&feats, c_used).unwrap();
            let b = mirror.predict(&feats, c_used);
            assert_eq!(a.len(), n);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert!(
                    (x - y).abs() < 2e-3,
                    "n={n} c={c_used} row {i}: pjrt {x} mirror {y}"
                );
                assert!((0.0..=1.0).contains(x));
            }
        }
    }
}

#[test]
fn pjrt_batching_is_consistent() {
    // Padding/batch selection must not change per-row results.
    let Some(dir) = pjrt_artifacts() else { return };
    let svc = RouterService::start(&dir).unwrap();
    let feats = rand_feats(32, 7);
    let full = svc.score(&feats, 0.3).unwrap();
    for i in [0usize, 3, 17, 31] {
        let single = svc.score(&feats[i..i + 1], 0.3).unwrap();
        assert!((full[i] - single[0]).abs() < 1e-5, "row {i}");
    }
}

#[test]
fn edge_lm_burn_runs() {
    let Some(dir) = pjrt_artifacts() else { return };
    let svc = RouterService::start(&dir).unwrap();
    assert!(svc.has_edge_lm());
    let c1 = svc.edge_burn(1).unwrap();
    let c2 = svc.edge_burn(3).unwrap();
    assert!(c1.is_finite() && c2.is_finite());
    // Deterministic input -> identical checksum.
    assert_eq!(c1, c2);
}

#[test]
fn full_pipeline_over_pjrt_predictor() {
    let Some(dir) = pjrt_artifacts() else { return };
    let svc = Arc::new(RouterService::start(&dir).unwrap());
    let sp = SimParams::default();
    let pipeline = HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        Arc::clone(&svc) as Arc<dyn UtilityPredictor>,
        PipelineConfig::paper_default(&sp),
    );
    let mut rng = Rng::new(0);
    let mut offloads = 0.0;
    let qs = generate_queries(Benchmark::Gpqa, 30, 0);
    for q in &qs {
        let out = pipeline.run_query(q, &mut rng);
        assert!(out.latency > 0.0);
        offloads += out.offload_rate;
    }
    // The trained router must actually route (not all-edge / all-cloud).
    let mean_off = offloads / qs.len() as f64;
    assert!((0.05..=0.95).contains(&mean_off), "offload {mean_off}");
}

#[test]
fn concurrent_serving_over_pjrt() {
    let Some(dir) = pjrt_artifacts() else { return };
    let svc = Arc::new(RouterService::start(&dir).unwrap());
    let sp = SimParams::default();
    let pipeline = Arc::new(HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        Arc::clone(&svc) as Arc<dyn UtilityPredictor>,
        PipelineConfig::paper_default(&sp),
    ));
    let qs = generate_queries(Benchmark::Gpqa, 40, 1);
    let report = hybridflow::server::serve(pipeline, qs, 6, 42);
    assert_eq!(report.n_queries, 40);
    assert!(report.throughput_qps > 1.0);
    assert!(report.accuracy_pct > 10.0);
}

#[test]
fn mirror_and_pjrt_agree_on_real_pipeline_features() {
    // Parity on *actual* packed features (not just random vectors).
    let Some(dir) = pjrt_artifacts() else { return };
    let svc = RouterService::start(&dir).unwrap();
    let mirror = MirrorPredictor::from_meta_file(&dir.join("router_meta.json")).unwrap();
    let sp = SimParams::default();
    let planner = SyntheticPlanner::paper_main();
    let mut rng = Rng::new(3);
    use hybridflow::embed::FeatureContext;
    use hybridflow::planner::Planner;
    for q in generate_queries(Benchmark::Aime24, 10, 3) {
        let plan = planner.plan(&q, 7, &mut rng);
        let latents = hybridflow::workload::sample_latents(&plan.dag, &q, &sp, &mut rng);
        let ctx = FeatureContext::new(&plan.dag, &q);
        let feats: Vec<_> = (0..plan.dag.len())
            .map(|i| ctx.features(&plan.dag, i, &latents[i], &sp, &mut rng))
            .collect();
        let a = svc.score(&feats, 0.2).unwrap();
        let b = mirror.predict(&feats, 0.2);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 2e-3, "pjrt {x} mirror {y}");
        }
    }
}

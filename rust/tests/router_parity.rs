//! Router-seam parity suite (PR 2 satellite).
//!
//! The scheduler used to resolve `RoutePolicy` with an inline enum match;
//! it now dispatches through `dyn Router`. Two guarantees are pinned here:
//!
//! 1. **Decision-for-decision parity** — `ReferenceRouter` below is a
//!    verbatim transcription of the pre-refactor enum match (the spec).
//!    For every policy variant, the trait path must produce the identical
//!    decision and threshold at every step of a long synthetic decision
//!    stream, including bandit feedback and RNG draws.
//! 2. **End-to-end offload-rate table** — full `QueryExecution` runs on a
//!    fixed seed grid must land on the analytically-known offload rates
//!    per policy (exact for the degenerate policies, banded for the
//!    stochastic/adaptive ones).

use hybridflow::budget::BudgetState;
use hybridflow::config::simparams::SimParams;
use hybridflow::models::SimExecutor;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::router::{LinUcb, MirrorPredictor, RoutePolicy, RouterState, Threshold};
use hybridflow::util::rng::Rng;
use hybridflow::workload::{generate_queries, Benchmark};
use std::sync::Arc;

/// Verbatim pre-refactor router: the enum match exactly as it stood before
/// the `Router` trait existed. Kept in the test as the behavioral spec.
struct ReferenceRouter {
    policy: RoutePolicy,
    bandit: LinUcb,
    tau_trace: Vec<f64>,
}

impl ReferenceRouter {
    fn new(policy: RoutePolicy) -> ReferenceRouter {
        ReferenceRouter { policy, bandit: LinUcb::paper_default(), tau_trace: Vec::new() }
    }

    fn decide(
        &mut self,
        sp: &SimParams,
        u_hat: f64,
        position: f64,
        budget: &BudgetState,
        oracle_ratio: Option<f64>,
        rng: &mut Rng,
    ) -> bool {
        match &mut self.policy {
            RoutePolicy::AllEdge => {
                self.tau_trace.push(1.0);
                false
            }
            RoutePolicy::AllCloud => {
                self.tau_trace.push(0.0);
                true
            }
            RoutePolicy::Random(p) => {
                self.tau_trace.push(1.0 - *p);
                rng.bernoulli(*p)
            }
            RoutePolicy::FixedThreshold(t) => {
                self.tau_trace.push(*t);
                u_hat > *t
            }
            RoutePolicy::Learned { threshold, calibrate } => {
                let tau = threshold.tau(budget);
                self.tau_trace.push(tau);
                let u_bar = if *calibrate {
                    let x = LinUcb::context(sp, u_hat, budget, position);
                    self.bandit.calibrated(&x)
                } else {
                    u_hat
                };
                let r = u_bar > tau;
                threshold.update(budget);
                r
            }
            RoutePolicy::Oracle => {
                let lambda = if budget.c_used >= sp.c_max { f64::INFINITY } else { 0.35 };
                self.tau_trace.push(0.0);
                oracle_ratio.map_or(false, |r| r > lambda)
            }
        }
    }

    fn observe_offloaded(
        &mut self,
        sp: &SimParams,
        u_hat: f64,
        position: f64,
        budget_at_decision: &BudgetState,
        realized_dq: f64,
        realized_c: f64,
    ) {
        if let RoutePolicy::Learned { calibrate: true, threshold } = &self.policy {
            let lambda = threshold.tau(budget_at_decision);
            let reward =
                (realized_dq - lambda * realized_c) / (realized_c + sp.eps_utility);
            let x = LinUcb::context(sp, u_hat, budget_at_decision, position);
            self.bandit.update(&x, reward.clamp(-1.0, 1.0));
        }
    }
}

fn policy_grid(sp: &SimParams) -> Vec<(&'static str, RoutePolicy)> {
    vec![
        ("all_edge", RoutePolicy::AllEdge),
        ("all_cloud", RoutePolicy::AllCloud),
        ("random", RoutePolicy::Random(0.37)),
        ("fixed", RoutePolicy::FixedThreshold(0.5)),
        ("fixed_tau", RoutePolicy::Learned { threshold: Threshold::Fixed(0.5), calibrate: false }),
        ("hybridflow", RoutePolicy::hybridflow(sp)),
        ("eq27", RoutePolicy::hybridflow_eq27(sp)),
        ("calibrated", RoutePolicy::hybridflow_calibrated(sp)),
        ("oracle", RoutePolicy::Oracle),
    ]
}

#[test]
fn trait_router_matches_reference_enum_decision_for_decision() {
    let sp = SimParams::default();
    for (name, policy) in policy_grid(&sp) {
        for seed in [7u64, 99, 4242] {
            let mut new_router = RouterState::new(policy.clone());
            let mut ref_router = ReferenceRouter::new(policy.clone());
            // Identical RNG streams: one for each path, same seed.
            let mut rng_new = Rng::new(seed);
            let mut rng_ref = Rng::new(seed);
            // Shared synthetic decision stream (inputs + budget evolution).
            let mut stream = Rng::new(seed ^ 0xDEC1DE);
            let mut budget = BudgetState::new();
            for step in 0..300 {
                let u_hat = stream.f64();
                let position = stream.f64();
                let ratio = stream.f64() * 2.0;
                let a = new_router.decide(
                    &sp, u_hat, position, &budget, Some(ratio), &mut rng_new,
                );
                let b = ref_router.decide(
                    &sp, u_hat, position, &budget, Some(ratio), &mut rng_ref,
                );
                assert_eq!(a, b, "{name}/seed{seed} step {step}: decision diverged");
                assert_eq!(
                    new_router.tau_trace.last(),
                    ref_router.tau_trace.last(),
                    "{name}/seed{seed} step {step}: tau diverged"
                );
                // Evolve the budget identically on both paths and feed the
                // partial-feedback channel on offloads.
                let snapshot = budget.clone();
                if a {
                    let dl = stream.f64() * 3.0;
                    let dk = stream.f64() * 0.002;
                    budget.record_cloud(&sp, dl, dk);
                    let dq = stream.f64() * 0.2;
                    let c = BudgetState::normalized_cost(&sp, dl, dk);
                    new_router.observe_offloaded(&sp, u_hat, position, &snapshot, dq, c);
                    ref_router.observe_offloaded(&sp, u_hat, position, &snapshot, dq, c);
                } else {
                    budget.record_edge();
                }
                if step % 17 == 0 {
                    budget.advance_latency(step as f64 * 0.1);
                }
            }
            // The RNG streams must have advanced in lockstep (no extra or
            // missing draws on either path).
            assert_eq!(
                rng_new.next_u64(),
                rng_ref.next_u64(),
                "{name}/seed{seed}: RNG streams out of sync"
            );
            assert_eq!(new_router.tau_trace.len(), ref_router.tau_trace.len());
            assert_eq!(new_router.bandit_updates(), ref_router.bandit.n_updates);
        }
    }
}

fn mean_offload(policy: RoutePolicy, seeds: &[u64], n: usize) -> f64 {
    let sp = SimParams::default();
    let mut cfg = PipelineConfig::paper_default(&sp);
    cfg.policy = policy;
    let pipeline = HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        Arc::new(MirrorPredictor::synthetic_for_tests()),
        cfg,
    );
    let mut total = 0.0;
    let mut count = 0usize;
    for &seed in seeds {
        let mut rng = Rng::new(seed ^ 0x0FF);
        for q in generate_queries(Benchmark::Gpqa, n, seed) {
            total += pipeline.run_query(&q, &mut rng).offload_rate;
            count += 1;
        }
    }
    total / count as f64
}

#[test]
fn offload_rate_table_on_fixed_seed_grid() {
    let seeds = [11u64, 22, 33];
    let n = 60;
    // (policy, expected offload rate, tolerance). The degenerate policies
    // are analytic and must be exact; Random matches its parameter to
    // sampling noise.
    let table: Vec<(RoutePolicy, f64, f64)> = vec![
        (RoutePolicy::AllEdge, 0.0, 0.0),
        (RoutePolicy::AllCloud, 1.0, 0.0),
        // u_hat can never exceed +inf / always exceeds -inf: strict-`>`
        // threshold semantics pin both ends regardless of predictor range.
        (RoutePolicy::FixedThreshold(f64::INFINITY), 0.0, 0.0),
        (RoutePolicy::FixedThreshold(f64::NEG_INFINITY), 1.0, 0.0),
        (RoutePolicy::Random(0.5), 0.5, 0.08),
        (RoutePolicy::Random(0.2), 0.2, 0.08),
    ];
    for (policy, expect, tol) in table {
        let label = policy.label();
        let rate = mean_offload(policy, &seeds, n);
        assert!(
            (rate - expect).abs() <= tol + 1e-12,
            "{label}: offload {rate} expected {expect} +/- {tol}"
        );
    }
    // Adaptive policies: partial offloading strictly inside (0, 1) on this
    // grid (the paper's ~40% regime).
    let sp = SimParams::default();
    for policy in [RoutePolicy::hybridflow(&sp), RoutePolicy::Oracle] {
        let label = policy.label();
        let rate = mean_offload(policy, &seeds, n);
        assert!(
            rate > 0.0 && rate < 1.0,
            "{label}: expected partial offloading, got {rate}"
        );
    }
}

//! Fault-injection + resilience integration tests: the degradation
//! paths the fuzz harness can only hit probabilistically, pinned as
//! deterministic scenarios.
//!
//! * A cloud outage covering the whole run, with every decision forced
//!   cloudward and failover disabled: every query must still complete —
//!   through edge degradation — with zero cloud dollars billed and the
//!   report byte-stable across reruns.
//! * A timeout storm (deadline far below any service time): bounded
//!   retries must terminate every query through degradation, with
//!   refunds keeping the books conserved.
//! * The shipped `scenarios/fleet_faulty.json`: report bytes independent
//!   of reruns, worker-thread counts, and the sharded-merge path.

use hybridflow::fault::{FaultConfig, OutageWindow, ResilienceConfig};
use hybridflow::router::{MirrorPredictor, UtilityPredictor};
use hybridflow::scenario::{
    EngineSpec, PolicySpec, ScenarioSpec, TenantSpec, TopologySpec, WorkloadSpec,
};
use hybridflow::workload::trace::ArrivalProcess;
use hybridflow::workload::Benchmark;
use std::path::PathBuf;
use std::sync::Arc;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn predictor() -> Arc<dyn UtilityPredictor> {
    Arc::new(MirrorPredictor::synthetic_for_tests())
}

fn base_spec(name: &str, n: usize) -> ScenarioSpec {
    ScenarioSpec {
        name: name.into(),
        seed: 17,
        topology: TopologySpec {
            edge_workers: 4,
            cloud_workers: 8,
            admission_limit: 16,
            global_k_cap: None,
            shards: 1,
            tenants: vec![TenantSpec { name: "t0".into(), k_cap: None, policy: None }],
        },
        workload: WorkloadSpec {
            benchmark: Benchmark::Gpqa,
            n,
            arrival: ArrivalProcess::Poisson { rate: 0.5 },
            zipf: None,
        },
        engine: EngineSpec { record_trace: true, ..EngineSpec::default() },
    }
}

#[test]
fn cloud_dark_whole_run_completes_every_query_via_edge_degradation() {
    let mut spec = base_spec("cloud_dark", 12);
    // Every decision forced cloudward, the cloud dark for any realistic
    // horizon, and failover disabled — so the only way out is the retry
    // ladder ending in edge degradation.
    spec.engine.policy = PolicySpec::AllCloud;
    spec.engine.faults = Some(FaultConfig {
        outages: vec![OutageWindow { cloud: true, start: 0.0, end: 1e12 }],
        ..FaultConfig::default()
    });
    spec.engine.resilience = Some(ResilienceConfig {
        timeout: None,
        max_retries: 2,
        backoff_base: 0.05,
        backoff_jitter: 0.1,
        failover_after: 0,
    });
    let session = spec.build(predictor()).unwrap();
    let a = session.run();
    let b = session.run();

    // 100% completion: the DAG never wedges.
    assert_eq!(a.results.len(), 12, "every query completes");
    let stats = a.faults.expect("fault layer reports stats");
    assert_eq!(stats.degraded_queries, 12, "every query finished degraded");
    assert!(stats.failures > 0, "outage rejections counted as failures");
    assert_eq!(stats.timeouts, 0);
    assert_eq!(stats.retries, stats.failures + stats.timeouts);

    // A dark cloud bills zero dollars, globally and per tenant.
    assert_eq!(a.total_api_cost, 0.0, "no cloud work happened, nothing billed");
    assert_eq!(a.global.k_spent, 0.0);
    for t in &a.tenants {
        assert_eq!(t.state.k_used, 0.0, "tenant '{}' spent cloud dollars", t.name);
    }

    for q in &a.results {
        assert!(
            q.exec.events.iter().any(|e| e.fault.degraded && !e.cloud),
            "query {} lacks an edge-side degraded completion",
            q.query_id
        );
        for e in &q.exec.events {
            if e.cloud {
                // Every cloud attempt was an instant outage rejection:
                // free, and occupying no worker time.
                assert!(e.fault.outage, "query {} node {} ran on a dark cloud", q.query_id, e.node);
                assert_eq!(e.api_cost, 0.0);
                assert_eq!(e.start, e.finish, "rejection held a worker");
            }
        }
    }

    // Byte-stable across reruns.
    assert_eq!(a.trace_text(), b.trace_text(), "rerun trace drifted");
    assert_eq!(
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty(),
        "rerun report drifted"
    );
}

#[test]
fn timeout_storm_terminates_through_bounded_retries() {
    let mut spec = base_spec("timeout_storm", 10);
    // A deadline far below any profiled service time: every attempt
    // times out until the retry budget is exhausted, then the degraded
    // attempt (fault checks suppressed) completes the node.
    spec.engine.faults = Some(FaultConfig { seed: 3, ..FaultConfig::default() });
    spec.engine.resilience = Some(ResilienceConfig {
        timeout: Some(1e-6),
        max_retries: 2,
        backoff_base: 0.01,
        backoff_jitter: 0.5,
        failover_after: 2,
    });
    let session = spec.build(predictor()).unwrap();
    let a = session.run();
    let b = session.run();

    assert_eq!(a.results.len(), 10, "every query completes");
    let stats = a.faults.expect("fault layer reports stats");
    assert_eq!(stats.degraded_queries, 10, "every query degraded after the storm");
    assert!(stats.timeouts > 0, "the storm fired");
    assert_eq!(stats.failures, 0, "no transient failures configured");
    assert_eq!(stats.retries, stats.failures + stats.timeouts);
    assert!(stats.refund.is_finite() && stats.refund >= 0.0, "refund {}", stats.refund);

    // The retry budget bounds every node's attempt ladder: attempts
    // 0..=2 time out, attempt 3 is the degraded completion.
    for q in &a.results {
        for e in &q.exec.events {
            assert!(
                e.fault.attempt <= 3,
                "query {} node {} reached attempt {}",
                q.query_id,
                e.node,
                e.fault.attempt
            );
        }
    }

    // Timeout refunds keep the books conserved.
    let tenant_sum: f64 = a.tenants.iter().map(|t| t.state.k_used).sum();
    assert!((a.global.k_spent - tenant_sum).abs() < 1e-9, "global vs tenant spend");
    assert!((a.total_api_cost - a.global.k_spent).abs() < 1e-9, "billed vs spent");

    assert_eq!(a.trace_text(), b.trace_text(), "rerun trace drifted");
    assert_eq!(
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty(),
        "rerun report drifted"
    );
}

#[test]
fn shipped_faulty_scenario_is_byte_stable_across_threads_and_shards() {
    let path = repo_root().join("scenarios").join("fleet_faulty.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    let spec = ScenarioSpec::parse(&text).unwrap();
    let session = spec.build(predictor()).unwrap();
    let a = session.run();
    let b = session.run();
    assert_eq!(a.trace_text(), b.trace_text(), "rerun trace drifted");
    assert_eq!(
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty(),
        "rerun report drifted"
    );
    let stats = a.faults.expect("fault layer reports stats");
    assert!(stats.attempts > 0, "the faulty fleet dispatched work");
    assert!(stats.failures + stats.timeouts > 0, "the preset's faults fired");

    // Fault realizations are attempt-addressed, so the bytes are
    // independent of worker-thread count and of the shard split.
    for shards in [1usize, 4] {
        let serial = session.run_sharded(shards, 1);
        let threaded = session.run_sharded(shards, 4);
        assert_eq!(
            serial.trace_text(),
            threaded.trace_text(),
            "shards={shards}: trace depends on thread count"
        );
        assert_eq!(
            serial.to_json().to_string_pretty(),
            threaded.to_json().to_string_pretty(),
            "shards={shards}: report depends on thread count"
        );
    }
    // shards = 1 through the sharded merge path matches the plain kernel.
    assert_eq!(
        session.run_sharded(1, 1).to_json().to_string_pretty(),
        a.to_json().to_string_pretty(),
        "sharded(1) drifted from the unsharded kernel"
    );
}

//! Substrate- and coordinator-level property tests (the proptest-style
//! deep-invariant suite; complements the per-module unit properties).

use hybridflow::budget::BudgetState;
use hybridflow::config::simparams::SimParams;
use hybridflow::dag::{
    emit_plan, parse_plan, validate, validate_and_repair, Role, Subtask, TaskDag,
};
use hybridflow::router::knapsack;
use hybridflow::testing::{forall, Gen};
use hybridflow::util::json::Json;
use hybridflow::util::rng::Rng;

// ---------------------------------------------------------------------------
// JSON substrate.
// ---------------------------------------------------------------------------

fn arbitrary_json(g: &mut Gen, depth: usize) -> Json {
    match if depth == 0 { g.usize_in(0..4) } else { g.usize_in(0..6) } {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num((g.f64_in(-1e6..1e6) * 1e3).round() / 1e3),
        3 => Json::Str(g.string(0..12)),
        4 => Json::Arr((0..g.size(4)).map(|_| arbitrary_json(g, depth - 1)).collect()),
        _ => Json::Obj(
            (0..g.size(4))
                .map(|i| (format!("k{i}_{}", g.string(0..4)), arbitrary_json(g, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip_identity() {
    forall("parse(write(v)) == v", 400, |g| {
        let v = arbitrary_json(g, 3);
        let compact = Json::parse(&v.to_string()).unwrap();
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        compact == v && pretty == v
    });
}

#[test]
fn prop_json_parser_never_panics_on_mutations() {
    forall("parser total on mutated inputs", 400, |g| {
        let v = arbitrary_json(g, 3);
        let mut text = v.to_string().into_bytes();
        if !text.is_empty() {
            // Flip a few bytes; parser must return Ok or Err, never panic.
            for _ in 0..g.usize_in(1..4) {
                let i = g.rng.below(text.len());
                text[i] = (g.rng.next_u64() % 256) as u8;
            }
        }
        if let Ok(s) = String::from_utf8(text) {
            let _ = Json::parse(&s);
        }
        true
    });
}

// ---------------------------------------------------------------------------
// DAG repair & XML.
// ---------------------------------------------------------------------------

fn arbitrary_dag(g: &mut Gen) -> TaskDag {
    let n = g.usize_in(1..11);
    let nodes = (0..n)
        .map(|i| {
            let role = match g.usize_in(0..3) {
                0 => Role::Explain,
                1 => Role::Analyze,
                _ => Role::Generate,
            };
            // Arbitrary (possibly invalid) deps: self-loops, forward edges,
            // out-of-range, duplicates.
            let ndeps = g.size(4);
            let deps: Vec<usize> = (0..ndeps).map(|_| g.rng.below(n + 2)).collect();
            let mut t = Subtask::new(i, role, &format!("step {i}"), deps.clone());
            t.edge_conf = deps.iter().map(|_| g.unit_f64()).collect();
            if g.bool() {
                t.req = vec![format!("sym{}", g.rng.below(4))];
            }
            if g.bool() {
                t.prod = vec![format!("sym{}", g.rng.below(4))];
            }
            t
        })
        .collect();
    TaskDag::new(nodes)
}

#[test]
fn prop_repair_always_yields_valid_dag() {
    forall("repair(any graph) is valid", 500, |g| {
        let dag = arbitrary_dag(g);
        let (out, _) = validate_and_repair(&dag, 7);
        validate(&out, 7).is_valid() && out.len() <= 7 && out.len() >= 2
    });
}

#[test]
fn prop_repair_is_idempotent() {
    forall("repair(repair(g)) == repair(g)", 200, |g| {
        let dag = arbitrary_dag(g);
        let (once, _) = validate_and_repair(&dag, 7);
        let (twice, outcome) = validate_and_repair(&once, 7);
        outcome == hybridflow::dag::RepairOutcome::Valid && twice == once
    });
}

#[test]
fn prop_xml_roundtrip_preserves_structure() {
    forall("parse(emit(valid dag)) == dag structure", 300, |g| {
        let dag = arbitrary_dag(g);
        let (valid, _) = validate_and_repair(&dag, 7);
        let xml = emit_plan(&valid);
        let back = parse_plan(&xml).expect("emitted plan must parse");
        back.len() == valid.len()
            && back
                .nodes
                .iter()
                .zip(&valid.nodes)
                .all(|(a, b)| a.deps == b.deps && a.role == b.role)
    });
}

#[test]
fn prop_topo_order_respects_all_edges() {
    forall("topo sound", 300, |g| {
        let dag = arbitrary_dag(g);
        let (valid, _) = validate_and_repair(&dag, 7);
        let order = valid.topo_order().expect("valid dag is acyclic");
        let pos: Vec<usize> =
            (0..valid.len()).map(|i| order.iter().position(|&x| x == i).unwrap()).collect();
        valid
            .nodes
            .iter()
            .all(|node| node.deps.iter().all(|&d| pos[d] < pos[node.id]))
    });
}

#[test]
fn prop_compression_ratio_bounds() {
    // R_comp in [0, (n-1)/n] (paper Eq. 28's stated extremes).
    forall("R_comp bounds", 300, |g| {
        let dag = arbitrary_dag(g);
        let (valid, _) = validate_and_repair(&dag, 7);
        let n = valid.len() as f64;
        let r = valid.compression_ratio().unwrap();
        (0.0..=(n - 1.0) / n + 1e-12).contains(&r)
    });
}

// ---------------------------------------------------------------------------
// Scheduler makespan bounds.
// ---------------------------------------------------------------------------

#[test]
fn prop_makespan_within_theoretical_bounds() {
    use hybridflow::engine::Backend;
    use hybridflow::models::SimExecutor;
    use hybridflow::router::{MirrorPredictor, RoutePolicy, RouterState};
    use hybridflow::scheduler::{execute_query, ScheduleConfig};
    use hybridflow::workload::{generate_queries, sample_latents, Benchmark};

    let executor = SimExecutor::paper_pair();
    let predictor = MirrorPredictor::synthetic_for_tests();
    forall("critical path <= makespan <= planning + sum", 150, |g| {
        let dag = arbitrary_dag(g);
        let (valid, _) = validate_and_repair(&dag, 7);
        let q = &generate_queries(Benchmark::Gpqa, 1, g.rng.next_u64() % 999)[0];
        let mut rng = Rng::new(g.rng.next_u64());
        let latents = sample_latents(&valid, q, executor.sp(), &mut rng);
        let planning = g.f64_in(0.5..3.0);
        let mut router = RouterState::new(RoutePolicy::Random(g.unit_f64()));
        let exec = execute_query(
            &valid, &latents, q, &executor, &predictor, &mut router, planning,
            &ScheduleConfig::default(), &mut rng,
        );
        let total: f64 = exec.events.iter().map(|e| e.finish - e.start).sum();
        let longest = exec.events.iter().map(|e| e.finish - e.start).fold(0.0, f64::max);
        exec.latency >= planning + longest - 1e-9 && exec.latency <= planning + total + 1e-9
    });
}

// ---------------------------------------------------------------------------
// Knapsack / budget.
// ---------------------------------------------------------------------------

#[test]
fn prop_knapsack_exact_dominates_and_respects_capacity() {
    forall("exact >= greedy, both feasible", 200, |g| {
        let n = g.usize_in(1..10);
        let v: Vec<f64> = (0..n).map(|_| g.unit_f64()).collect();
        let w: Vec<f64> = (0..n).map(|_| g.f64_in(0.01..0.4)).collect();
        let cap = g.f64_in(0.0..1.2);
        let (ve, pe) = knapsack::solve_exact(&v, &w, cap);
        let (vg, _) = knapsack::solve_greedy_ratio(&v, &w, cap);
        let we: f64 = pe.iter().zip(&w).filter(|(p, _)| **p).map(|(_, x)| x).sum();
        ve + 1e-12 >= vg && we <= cap + 1e-9
    });
}

#[test]
fn prop_budget_accumulation_monotone_and_bounded() {
    let sp = SimParams::default();
    forall("budget monotone", 300, |g| {
        let mut b = BudgetState::new();
        let mut last_c = 0.0;
        for _ in 0..g.usize_in(0..30) {
            if g.bool() {
                b.record_cloud(&sp, g.f64_in(0.0..20.0), g.f64_in(0.0..0.05));
            } else {
                b.record_edge();
            }
            if b.c_used < last_c - 1e-12 {
                return false;
            }
            last_c = b.c_used;
        }
        // Each cloud record adds at most 1.0 of normalized cost.
        b.c_used <= b.n_offloaded as f64 + 1e-9 && b.offload_rate() <= 1.0
    });
}

// ---------------------------------------------------------------------------
// Exposure metric.
// ---------------------------------------------------------------------------

#[test]
fn prop_exposure_bounded_and_consistent() {
    use hybridflow::metrics::exposure::Exposure;
    use hybridflow::engine::Backend;
    use hybridflow::models::SimExecutor;
    use hybridflow::router::{MirrorPredictor, RoutePolicy, RouterState};
    use hybridflow::scheduler::{execute_query, ScheduleConfig};
    use hybridflow::workload::{generate_queries, sample_latents, Benchmark};

    let executor = SimExecutor::paper_pair();
    let predictor = MirrorPredictor::synthetic_for_tests();
    forall("0 <= E_bar <= 1; cloud calls == offloads", 100, |g| {
        let dag = arbitrary_dag(g);
        let (valid, _) = validate_and_repair(&dag, 7);
        let q = &generate_queries(Benchmark::MmluPro, 1, g.rng.next_u64() % 999)[0];
        let mut rng = Rng::new(g.rng.next_u64());
        let latents = sample_latents(&valid, q, executor.sp(), &mut rng);
        let mut router = RouterState::new(RoutePolicy::Random(g.unit_f64()));
        let exec = execute_query(
            &valid, &latents, q, &executor, &predictor, &mut router, 1.0,
            &ScheduleConfig::default(), &mut rng,
        );
        let e = Exposure::from_events(&exec.events);
        let nb = e.normalized();
        (nb.is_nan() || (0.0..=1.0).contains(&nb))
            && e.n_cloud_calls == exec.budget.n_offloaded
    });
}

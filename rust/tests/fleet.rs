//! Fleet-simulator integration suite:
//!
//! * **equivalence** — a fleet with one tenant, one query, and unlimited
//!   pools reproduces `run_query_traced` *exactly* (same RNG stream, same
//!   event order), across policies, schedules, and seeds;
//! * **golden trace** — a fixed-seed 3-tenant workload serializes to a
//!   byte-stable event trace pinned by a checked-in golden file;
//! * **properties** (`testing::forall`) — virtual-clock monotonicity,
//!   shared-pool occupancy bounds, and tenant-spend caps hold across
//!   randomized fleets.

use hybridflow::budget::TenantPool;
use hybridflow::config::simparams::SimParams;
use hybridflow::models::SimExecutor;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::router::{MirrorPredictor, RoutePolicy};
use hybridflow::scheduler::fleet::{run_fleet, FleetArrival, FleetConfig, FleetReport};
use hybridflow::scheduler::ScheduleConfig;
use hybridflow::testing::forall;
use hybridflow::util::rng::Rng;
use hybridflow::workload::{generate_queries, Benchmark};
use std::path::PathBuf;
use std::sync::Arc;

fn pipeline_with(policy: RoutePolicy, schedule: ScheduleConfig) -> HybridFlowPipeline {
    let sp = SimParams::default();
    let mut cfg = PipelineConfig::paper_default(&sp);
    cfg.policy = policy;
    cfg.schedule = schedule;
    HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        Arc::new(MirrorPredictor::synthetic_for_tests()),
        cfg,
    )
}

fn single_tenant() -> Vec<TenantPool> {
    vec![TenantPool::unlimited("solo")]
}

/// The per-query RNG seed formula used by `run_fleet` for job index `i`.
fn job_seed(seed: u64, i: u64) -> u64 {
    seed ^ i.wrapping_mul(0x9E3779B97f4A7C15)
}

// ---------------------------------------------------------------------------
// Equivalence: fleet(N=1) == run_query.
// ---------------------------------------------------------------------------

fn assert_exec_equal(
    fleet: &hybridflow::scheduler::QueryExecution,
    solo: &hybridflow::scheduler::QueryExecution,
    label: &str,
) {
    assert_eq!(fleet.correct, solo.correct, "{label}: correct");
    assert_eq!(fleet.latency, solo.latency, "{label}: latency");
    assert_eq!(fleet.api_cost, solo.api_cost, "{label}: api_cost");
    assert_eq!(fleet.offload_rate, solo.offload_rate, "{label}: offload_rate");
    assert_eq!(fleet.n_subtasks, solo.n_subtasks, "{label}: n_subtasks");
    assert_eq!(fleet.budget.c_used, solo.budget.c_used, "{label}: c_used");
    assert_eq!(fleet.budget.k_used, solo.budget.k_used, "{label}: k_used");
    assert_eq!(fleet.budget.l_used, solo.budget.l_used, "{label}: l_used");
    assert_eq!(fleet.events.len(), solo.events.len(), "{label}: event count");
    for (i, (a, b)) in fleet.events.iter().zip(&solo.events).enumerate() {
        assert_eq!(a.node, b.node, "{label}: event {i} node");
        assert_eq!(a.cloud, b.cloud, "{label}: event {i} side");
        assert_eq!(a.tau, b.tau, "{label}: event {i} tau");
        assert_eq!(a.u_hat, b.u_hat, "{label}: event {i} u_hat");
        assert_eq!(a.start, b.start, "{label}: event {i} start");
        assert_eq!(a.finish, b.finish, "{label}: event {i} finish");
        assert_eq!(a.api_cost, b.api_cost, "{label}: event {i} api_cost");
        assert_eq!(a.in_tokens, b.in_tokens, "{label}: event {i} in_tokens");
    }
}

#[test]
fn fleet_single_query_reproduces_run_query_exactly() {
    let sp = SimParams::default();
    let policies: Vec<(&str, RoutePolicy)> = vec![
        ("hybridflow", RoutePolicy::hybridflow(&sp)),
        ("eq27", RoutePolicy::hybridflow_eq27(&sp)),
        ("calibrated", RoutePolicy::hybridflow_calibrated(&sp)),
        ("all_cloud", RoutePolicy::AllCloud),
        ("all_edge", RoutePolicy::AllEdge),
        ("random", RoutePolicy::Random(0.5)),
        ("fixed", RoutePolicy::FixedThreshold(0.4)),
        ("oracle", RoutePolicy::Oracle),
    ];
    let schedules: Vec<(&str, ScheduleConfig)> = vec![
        ("default", ScheduleConfig::default()),
        ("chain", ScheduleConfig { chain_mode: true, ..Default::default() }),
        ("unbatched", ScheduleConfig { batch_frontier: false, ..Default::default() }),
        ("narrow", ScheduleConfig { edge_workers: 2, cloud_workers: 2, ..Default::default() }),
        // Speculative dual dispatch: the cancel/refund machinery must also
        // reduce to the single-query scheduler at N=1.
        ("hedged", ScheduleConfig { hedge: true, hedge_threshold: 0.3, ..Default::default() }),
    ];
    for (pname, policy) in &policies {
        for (sname, schedule) in &schedules {
            for seed in [3u64, 17, 404] {
                let label = format!("{pname}/{sname}/seed{seed}");
                let pipeline = pipeline_with(policy.clone(), schedule.clone());
                let query = generate_queries(Benchmark::Gpqa, 1, seed).pop().unwrap();

                // Reference: the per-query scheduler, on the exact RNG the
                // fleet will fork for job 0.
                let mut rng = Rng::new(job_seed(seed, 0));
                let (solo, _) = pipeline.run_query_traced(&query, &mut rng);

                let report = run_fleet(
                    &pipeline,
                    &FleetConfig::default(),
                    single_tenant(),
                    vec![FleetArrival { time: 0.0, tenant: 0, query }],
                    seed,
                );
                assert_eq!(report.results.len(), 1);
                let r = &report.results[0];
                assert_eq!(r.forced_edge, 0, "{label}: unlimited pools never force edge");
                assert_exec_equal(&r.exec, &solo, &label);
                // Tenant aggregate == the single query's budget.
                assert_eq!(report.tenants[0].state.c_used, solo.budget.c_used, "{label}");
                assert_eq!(report.tenants[0].state.k_used, solo.budget.k_used, "{label}");
            }
        }
    }
}

#[test]
fn widely_spaced_first_query_unaffected_by_successors() {
    // With a huge arrival gap the first query runs uncontended, so it must
    // still match the per-query scheduler bit-for-bit even though a second
    // query exists in the fleet.
    let sp = SimParams::default();
    let pipeline = pipeline_with(RoutePolicy::hybridflow(&sp), ScheduleConfig::default());
    let seed = 29u64;
    let queries = generate_queries(Benchmark::MmluPro, 2, seed);

    let mut rng = Rng::new(job_seed(seed, 0));
    let (solo, _) = pipeline.run_query_traced(&queries[0], &mut rng);

    let arrivals = vec![
        FleetArrival { time: 0.0, tenant: 0, query: queries[0].clone() },
        FleetArrival { time: 1e9, tenant: 0, query: queries[1].clone() },
    ];
    let report =
        run_fleet(&pipeline, &FleetConfig::default(), single_tenant(), arrivals, seed);
    assert_exec_equal(&report.results[0].exec, &solo, "first-of-two");
    // The second query completed too (no deadlock across the gap).
    assert!(report.results[1].completed_at > 1e9);
}

// ---------------------------------------------------------------------------
// Golden trace.
// ---------------------------------------------------------------------------

/// The pinned golden fleet, parameterized over per-query scheduling so
/// regression tests can vary knobs (e.g. touched-but-off hedge fields)
/// against the one canonical workload definition.
fn golden_workload_with(schedule: ScheduleConfig) -> FleetReport {
    let sp = SimParams::default();
    let pipeline = pipeline_with(RoutePolicy::hybridflow(&sp), schedule);
    let tenants = vec![
        TenantPool::unlimited("anchor"),
        TenantPool::new("metered", 0.02),
        TenantPool::new("capped", 0.001),
    ];
    let arrivals: Vec<FleetArrival> = generate_queries(Benchmark::Gpqa, 12, 1234)
        .into_iter()
        .enumerate()
        .map(|(i, query)| FleetArrival { time: i as f64 * 1.5, tenant: i % 3, query })
        .collect();
    run_fleet(&pipeline, &FleetConfig::default(), tenants, arrivals, 1234)
}

fn golden_schedule() -> ScheduleConfig {
    ScheduleConfig { edge_workers: 4, cloud_workers: 8, ..Default::default() }
}

fn golden_workload() -> FleetReport {
    golden_workload_with(golden_schedule())
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/fleet_trace.txt")
}

/// Byte-stable golden trace for a fixed-seed 3-tenant fleet.
///
/// Regenerate (after an intentional engine change) with:
/// `rm rust/tests/golden/fleet_trace.txt && cargo test --test fleet golden_trace`
/// — the test bootstraps the file when absent (verifying two independent
/// runs agree first) and strictly compares when present.
#[test]
fn golden_trace_three_tenant_fleet() {
    let first = golden_workload().trace_text();
    let second = golden_workload().trace_text();
    assert_eq!(first, second, "fleet trace is not deterministic within-process");
    assert!(first.lines().count() > 50, "golden workload too small to pin behavior");

    let path = golden_path();
    if path.exists() {
        let pinned = std::fs::read_to_string(&path).expect("read golden file");
        assert_eq!(
            first, pinned,
            "fleet trace diverged from {} — if the change is intentional, delete the file \
             and rerun this test to regenerate",
            path.display()
        );
    } else {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).expect("create golden dir");
        }
        std::fs::write(&path, &first).expect("write golden file");
        eprintln!("[golden_trace] bootstrapped {}", path.display());
    }
}

/// Satellite regression: with hedging off, the refactored engine (Backend
/// + Router seams, shared event ordering, cancel machinery) must reproduce
/// the pre-refactor fleet trace byte-for-byte. We run the exact golden
/// workload through a pipeline whose hedge knobs were touched and turned
/// back off, and require byte-identity with the default-config trace and
/// with the pinned golden file when present.
#[test]
fn hedge_off_reproduces_golden_trace() {
    let base = golden_workload().trace_text();

    let mut schedule = golden_schedule();
    schedule.hedge = false; // explicit off
    schedule.hedge_threshold = 0.123; // knob touched: must be inert
    let touched = golden_workload_with(schedule).trace_text();

    assert_eq!(touched, base, "hedge=off must be byte-identical to the default engine");
    let path = golden_path();
    if path.exists() {
        let pinned = std::fs::read_to_string(&path).expect("read golden file");
        assert_eq!(
            touched, pinned,
            "hedge=off trace diverged from the pinned golden file {}",
            path.display()
        );
    }
}

/// Satellite regression (PR 3): with the result cache disabled — either
/// not attached, or attached with capacity 0 (the CLI's `--cache 0`) —
/// the engine must reproduce the PR 2 fleet trace byte-for-byte. A
/// capacity-0 cache must be *fully* inert: its probe path consumes no RNG
/// and its insert path stores nothing.
#[test]
fn cache_off_reproduces_golden_trace() {
    use hybridflow::cache::{CachePolicyKind, SubtaskCache};

    let base = golden_workload().trace_text();

    let mut schedule = golden_schedule();
    schedule.cache = Some(Arc::new(SubtaskCache::new(0, CachePolicyKind::Lru)));
    let zero_cap = golden_workload_with(schedule).trace_text();

    assert_eq!(
        zero_cap, base,
        "--cache 0 must be byte-identical to the uncached engine"
    );
    let path = golden_path();
    if path.exists() {
        let pinned = std::fs::read_to_string(&path).expect("read golden file");
        assert_eq!(
            zero_cap, pinned,
            "cache-off trace diverged from the pinned golden file {}",
            path.display()
        );
    }
}

/// Single-query counterpart of the golden pin: `--cache 0` leaves
/// `execute_query` outcomes bit-identical across a policy grid.
#[test]
fn cache_off_single_query_is_bit_identical() {
    use hybridflow::cache::{CachePolicyKind, SubtaskCache};

    let sp = SimParams::default();
    for policy in [
        RoutePolicy::hybridflow(&sp),
        RoutePolicy::Random(0.5),
        RoutePolicy::AllCloud,
    ] {
        for seed in [2u64, 71, 909] {
            let plain = pipeline_with(policy.clone(), ScheduleConfig::default());
            let mut zero_sched = ScheduleConfig::default();
            zero_sched.cache = Some(Arc::new(SubtaskCache::new(0, CachePolicyKind::Lfu)));
            let zeroed = pipeline_with(policy.clone(), zero_sched);
            let query = generate_queries(Benchmark::Gpqa, 1, seed).pop().unwrap();
            let mut r1 = Rng::new(job_seed(seed, 0));
            let mut r2 = Rng::new(job_seed(seed, 0));
            let (a, _) = plain.run_query_traced(&query, &mut r1);
            let (b, _) = zeroed.run_query_traced(&query, &mut r2);
            assert_exec_equal(&b, &a, &format!("{}/seed{seed}", policy.label()));
            // The RNG streams advanced in lockstep too.
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
    }
}

/// Satellite regression (worker-pool overhaul): the kernel driven by the
/// retained linear-scan reference pools
/// (`ScheduleConfig::linear_pool_reference`) must reproduce the indexed
/// kernel's golden workload byte-for-byte — the O(log W) index changes
/// dispatch *cost*, never dispatch *choice*.
#[test]
fn linear_reference_pools_reproduce_golden_trace() {
    let indexed = golden_workload().trace_text();
    let mut schedule = golden_schedule();
    schedule.linear_pool_reference = true;
    let linear = golden_workload_with(schedule).trace_text();
    assert_eq!(
        linear, indexed,
        "linear-scan reference pools must be byte-identical to the ordered index"
    );
    let path = golden_path();
    if path.exists() {
        let pinned = std::fs::read_to_string(&path).expect("read golden file");
        assert_eq!(linear, pinned, "linear-reference trace diverged from the pinned golden");
    }
}

/// Satellite regression (utilization denominators): a side configured
/// with zero workers carries a phantom claim slot internally (the claim
/// path must stay total) but has no real capacity — utilization must
/// report 0.0 instead of busy time against the phantom worker.
#[test]
fn zero_worker_side_reports_zero_utilization() {
    let schedule = ScheduleConfig { edge_workers: 0, cloud_workers: 4, ..Default::default() };
    let pipeline = pipeline_with(RoutePolicy::AllEdge, schedule);
    let seed = 77u64;
    let arrivals: Vec<FleetArrival> = generate_queries(Benchmark::Gpqa, 4, seed)
        .into_iter()
        .enumerate()
        .map(|(i, query)| FleetArrival { time: i as f64 * 1.0, tenant: 0, query })
        .collect();
    let cfg = FleetConfig { record_trace: false, ..Default::default() };
    let report = run_fleet(&pipeline, &cfg, single_tenant(), arrivals, seed);
    // All-edge work ran on the phantom slot: busy time exists, but the
    // configured capacity is zero, so the side reports no utilization.
    assert!(
        report.results.iter().flat_map(|r| r.exec.events.iter()).all(|e| !e.cloud),
        "all-edge policy keeps the cloud side idle"
    );
    assert!(
        report.results.iter().any(|r| !r.exec.events.is_empty()),
        "queries executed on the phantom slot"
    );
    assert_eq!(report.edge_utilization, 0.0, "no phantom-worker utilization");
    assert_eq!(report.cloud_utilization, 0.0, "idle side stays at zero");

    // Sanity: the same workload with one real edge worker reports busy
    // time against that worker.
    let pipeline = pipeline_with(
        RoutePolicy::AllEdge,
        ScheduleConfig { edge_workers: 1, ..Default::default() },
    );
    let arrivals: Vec<FleetArrival> = generate_queries(Benchmark::Gpqa, 4, seed)
        .into_iter()
        .enumerate()
        .map(|(i, query)| FleetArrival { time: i as f64 * 1.0, tenant: 0, query })
        .collect();
    let report = run_fleet(&pipeline, &cfg, single_tenant(), arrivals, seed);
    assert!(report.edge_utilization > 0.0, "configured workers report real utilization");
}

// ---------------------------------------------------------------------------
// Properties.
// ---------------------------------------------------------------------------

/// Max concurrent intervals, treating the end as exclusive (a worker freed
/// at `t` may start a new task at `t`).
fn max_overlap(mut intervals: Vec<(f64, f64)>) -> usize {
    let mut points: Vec<(f64, i32)> = Vec::with_capacity(intervals.len() * 2);
    for (s, f) in intervals.drain(..) {
        points.push((s, 1));
        points.push((f, -1));
    }
    // At equal times, process releases before acquires.
    points.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut cur = 0i32;
    let mut best = 0i32;
    for (_, d) in points {
        cur += d;
        best = best.max(cur);
    }
    best.max(0) as usize
}

#[test]
fn prop_fleet_pool_occupancy_and_clock() {
    let sp = SimParams::default();
    forall("edge/cloud occupancy within pool bounds; clock monotone", 25, move |g| {
        let edge_workers = g.usize_in(1..4);
        let cloud_workers = g.usize_in(1..5);
        let n = g.usize_in(2..9);
        let gap = g.f64_in(0.0..3.0);
        let policy = match g.usize_in(0..3) {
            0 => RoutePolicy::hybridflow(&sp),
            1 => RoutePolicy::Random(g.unit_f64()),
            _ => RoutePolicy::AllCloud,
        };
        let schedule = ScheduleConfig {
            edge_workers,
            cloud_workers,
            batch_frontier: g.bool(),
            chain_mode: false,
            ..Default::default()
        };
        let pipeline = pipeline_with(policy, schedule);
        let seed = g.rng.next_u64() % 10_000;
        let arrivals: Vec<FleetArrival> = generate_queries(Benchmark::Gpqa, n, seed)
            .into_iter()
            .enumerate()
            .map(|(i, query)| FleetArrival { time: i as f64 * gap, tenant: 0, query })
            .collect();
        let cfg = FleetConfig { record_trace: false, ..Default::default() };
        let report = run_fleet(&pipeline, &cfg, single_tenant(), arrivals, seed);

        let mut edge_iv = Vec::new();
        let mut cloud_iv = Vec::new();
        for r in &report.results {
            for e in &r.exec.events {
                if e.cloud {
                    cloud_iv.push((e.start, e.finish));
                } else {
                    edge_iv.push((e.start, e.finish));
                }
            }
        }
        report.clock_monotone
            && max_overlap(edge_iv) <= edge_workers
            && max_overlap(cloud_iv) <= cloud_workers
            && report.results.iter().all(|r| {
                r.admitted >= r.arrival - 1e-9 && r.completed_at >= r.plan_done - 1e-9
            })
    });
}

#[test]
fn prop_tenant_spend_never_exceeds_pool_by_more_than_one_call() {
    forall("tenant spend bounded by cap + one call", 25, move |g| {
        let cap_a = g.f64_in(0.0..0.01);
        let cap_b = g.f64_in(0.0..0.002);
        let n = g.usize_in(4..10);
        // All-cloud pressure maximizes spend against the caps.
        let pipeline = pipeline_with(RoutePolicy::AllCloud, ScheduleConfig::default());
        let seed = g.rng.next_u64() % 10_000;
        let arrivals: Vec<FleetArrival> = generate_queries(Benchmark::Gpqa, n, seed)
            .into_iter()
            .enumerate()
            .map(|(i, query)| FleetArrival { time: i as f64 * 2.0, tenant: i % 2, query })
            .collect();
        let tenants = vec![TenantPool::new("a", cap_a), TenantPool::new("b", cap_b)];
        let cfg = FleetConfig { record_trace: false, ..Default::default() };
        let report = run_fleet(&pipeline, &cfg, tenants, arrivals, seed);

        let max_call = report
            .results
            .iter()
            .flat_map(|r| r.exec.events.iter())
            .map(|e| e.api_cost)
            .fold(0.0f64, f64::max);
        let tenant_sum: f64 = report.tenants.iter().map(|t| t.state.k_used).sum();
        report
            .tenants
            .iter()
            .all(|t| t.state.k_used <= t.k_cap + max_call + 1e-12)
            && (report.global.k_spent - tenant_sum).abs() < 1e-9
    });
}

#[test]
fn prop_hedged_refunds_keep_spend_bounded_and_consistent() {
    // Satellite property: cancelled hedged calls never leave a tenant pool
    // above its cap by more than one call's billed cost, refunds never
    // drive any dollar scope negative, and the global ledger always equals
    // the tenant sum (spend and refunds are recorded symmetrically).
    let sp = SimParams::default();
    forall("hedged spend within [0, cap + one call]; global == tenant sum", 25, move |g| {
        let cap_a = g.f64_in(0.0..0.01);
        let cap_b = g.f64_in(0.0..0.002);
        let n = g.usize_in(4..10);
        let policy = match g.usize_in(0..3) {
            0 => RoutePolicy::AllEdge,
            1 => RoutePolicy::FixedThreshold(g.f64_in(0.3..0.9)),
            _ => RoutePolicy::hybridflow(&sp),
        };
        let schedule = ScheduleConfig {
            hedge: true,
            hedge_threshold: g.f64_in(0.0..0.7),
            edge_workers: g.usize_in(1..3),
            ..Default::default()
        };
        let pipeline = pipeline_with(policy, schedule);
        let seed = g.rng.next_u64() % 10_000;
        let arrivals: Vec<FleetArrival> = generate_queries(Benchmark::Gpqa, n, seed)
            .into_iter()
            .enumerate()
            .map(|(i, query)| FleetArrival { time: i as f64 * 1.0, tenant: i % 2, query })
            .collect();
        let tenants = vec![TenantPool::new("a", cap_a), TenantPool::new("b", cap_b)];
        let cfg = FleetConfig { record_trace: false, ..Default::default() };
        let report = run_fleet(&pipeline, &cfg, tenants, arrivals, seed);

        // Events record the dispatch-time bill (full speculative cost), so
        // the max event bill bounds any single call's overshoot.
        let max_call = report
            .results
            .iter()
            .flat_map(|r| r.exec.events.iter())
            .map(|e| e.api_cost)
            .fold(0.0f64, f64::max);
        let tenant_sum: f64 = report.tenants.iter().map(|t| t.state.k_used).sum();
        report
            .tenants
            .iter()
            .all(|t| t.state.k_used >= 0.0 && t.state.k_used <= t.k_cap + max_call + 1e-12)
            && report.tenants.iter().all(|t| t.state.c_used >= 0.0)
            && report.global.k_spent >= 0.0
            && (report.global.k_spent - tenant_sum).abs() < 1e-9
            && report.hedge_refund >= 0.0
    });
}

#[test]
fn prop_trace_times_nondecreasing() {
    let sp = SimParams::default();
    forall("recorded trace is chronologically ordered", 15, move |g| {
        let n = g.usize_in(2..7);
        let pipeline =
            pipeline_with(RoutePolicy::hybridflow(&sp), ScheduleConfig::default());
        let seed = g.rng.next_u64() % 10_000;
        let arrivals: Vec<FleetArrival> = generate_queries(Benchmark::LiveBench, n, seed)
            .into_iter()
            .enumerate()
            .map(|(i, query)| {
                FleetArrival { time: g.f64_in(0.0..5.0) + i as f64 * 0.5, tenant: 0, query }
            })
            .collect();
        let report =
            run_fleet(&pipeline, &FleetConfig::default(), single_tenant(), arrivals, seed);
        let times: Vec<f64> = report
            .trace
            .iter()
            .map(|line| {
                let t = line.strip_prefix("t=").and_then(|r| r.split(' ').next()).unwrap();
                t.parse::<f64>().unwrap()
            })
            .collect();
        !times.is_empty() && times.windows(2).all(|w| w[0] <= w[1] + 1e-9)
    });
}

//! Bounded fuzz pass + regression-corpus replay.
//!
//! The corpus (`rust/tests/corpus/*.json`) is replayed first: every bug
//! the fuzz harness ever flushed out is checked in as a minimized spec.
//! `reject_*.json` files must fail `ScenarioSpec::parse` (validation
//! regressions); `run_*.json` files must parse and hold every kernel
//! invariant (crash/behavior regressions); `check_*.json` files must
//! parse but draw an error from the static feasibility checker
//! (`hybridflow check --scenario` regressions). Then a bounded
//! randomized sweep runs fresh specs — case count via
//! `HYBRIDFLOW_FUZZ_CASES` (default 64; CI keeps it small,
//! `hybridflow fuzz` goes deep).
//!
//! A failing case prints the full spec JSON plus a one-line repro:
//! `hybridflow fuzz --cases 1 --seed <base+case> [--adversarial]`.

use hybridflow::scenario::ScenarioSpec;
use hybridflow::testing::fuzz::{failure_report, run_case, spec_for_case};
use std::path::PathBuf;

fn cases() -> usize {
    std::env::var("HYBRIDFLOW_FUZZ_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/corpus")
}

#[test]
fn corpus_replays_clean() {
    let dir = corpus_dir();
    let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {}: {e}", dir.display()))
        .map(|entry| entry.expect("corpus dir entry").path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    files.sort();
    assert!(files.len() >= 8, "corpus unexpectedly small: {} file(s)", files.len());
    for path in files {
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        let text = std::fs::read_to_string(&path).expect("read corpus spec");
        if name.starts_with("reject_") {
            assert!(
                ScenarioSpec::parse(&text).is_err(),
                "{name}: spec must be rejected at parse (validation regression)"
            );
        } else if name.starts_with("run_") {
            let spec = ScenarioSpec::parse(&text)
                .unwrap_or_else(|e| panic!("{name}: corpus spec must parse: {e}"));
            let violations = run_case(&spec);
            assert!(
                violations.is_empty(),
                "{name}: corpus spec violated invariants:\n  - {}",
                violations.join("\n  - ")
            );
        } else if name.starts_with("check_") {
            let spec = ScenarioSpec::parse(&text)
                .unwrap_or_else(|e| panic!("{name}: corpus spec must parse: {e}"));
            let report = hybridflow::analysis::scenario::check_spec(&spec);
            assert!(
                !report.passed(),
                "{name}: spec must draw a feasibility error:\n{}",
                report.render()
            );
        } else {
            panic!(
                "corpus file '{name}' must be named reject_*.json, run_*.json, or check_*.json"
            );
        }
    }
}

#[test]
fn random_specs_hold_all_invariants() {
    let base = 0xF00D;
    for case in 0..cases() {
        let spec = spec_for_case(base, case, false);
        let violations = run_case(&spec);
        assert!(violations.is_empty(), "{}", failure_report(&spec, base, case, false, &violations));
    }
}

#[test]
fn adversarial_specs_hold_all_invariants() {
    let base = 0xF00D;
    for case in 0..cases() {
        let spec = spec_for_case(base, case, true);
        let violations = run_case(&spec);
        assert!(violations.is_empty(), "{}", failure_report(&spec, base, case, true, &violations));
    }
}

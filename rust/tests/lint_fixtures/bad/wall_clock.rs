// Seeded-bad fixture: `hybridflow lint` must flag the wall_clock rule
// here. Not compiled into any cargo target.

pub fn stamp() -> f64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn epoch() -> std::time::SystemTime {
    std::time::SystemTime::now()
}

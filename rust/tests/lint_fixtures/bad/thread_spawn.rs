// Seeded-bad fixture: `hybridflow lint` must flag the thread_spawn rule
// here. Not compiled into any cargo target.

pub fn fan_out() -> i32 {
    let h = std::thread::spawn(|| 1 + 1);
    h.join().unwrap_or(0)
}

// Seeded-bad fixture: `hybridflow lint` must flag the print_in_lib rule
// here (the fixture path is not main.rs and not under report/). Not
// compiled into any cargo target.

pub fn report(x: f64) {
    println!("value = {x}");
    eprintln!("warn = {x}");
}

// Seeded-bad fixture: `hybridflow lint` must flag the partial_cmp_unwrap
// rule here (rust/tests/analysis.rs + scripts/verify.sh assert nonzero
// exit). Not compiled into any cargo target.

pub fn pick_max(v: &mut [f64]) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn pick_named(v: &mut [f64]) {
    v.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
}

// Seeded-bad fixture: `hybridflow lint` must flag the float_int_cast
// rule here — the fixture sits under a `sim/` path segment, so the
// kernel-path scoping applies. Not compiled into any cargo target.

pub fn bucket(x: f64, n: usize) -> usize {
    (x * n as f64).floor() as usize
}

// Seeded-bad fixture: `hybridflow lint` must flag the
// unordered_float_sum rule here (a `.sum::<f64>()` with a hash
// collection in the same statement; the HashMap mentions also draw
// hash_collection findings). Not compiled into any cargo target.

use std::collections::HashMap;

pub fn total(xs: &[(u64, f64)]) -> f64 {
    xs.iter().copied().collect::<HashMap<u64, f64>>().values().sum::<f64>()
}

// Seeded-bad fixture: `hybridflow lint` must flag the hash_collection
// rule here. Not compiled into any cargo target.

use std::collections::HashMap;

pub fn tally(xs: &[u64]) -> HashMap<u64, usize> {
    let mut m = HashMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0) += 1;
    }
    m
}

// Allow-annotated twins of the seeded-bad fixtures: every hazard below
// carries a justified `lint:allow`, so `hybridflow lint` must stay
// silent on this file. Not compiled into any cargo target.

// lint:allow(hash_collection): fixture exercises a justified suppression
use std::collections::HashMap;

pub fn pick_max(v: &mut [f64]) {
    // lint:allow(partial_cmp_unwrap): fixture exercises a justified suppression
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn stamp() -> f64 {
    // lint:allow(wall_clock): fixture exercises a justified suppression
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn fan_out() -> i32 {
    // lint:allow(thread_spawn): fixture exercises a justified suppression
    let h = std::thread::spawn(|| 1 + 1);
    h.join().unwrap_or(0)
}

pub fn report(x: f64) {
    println!("value = {x}"); // lint:allow(print_in_lib): trailing-form suppression
}

pub fn total(xs: &[(u64, f64)]) -> f64 {
    // lint:allow(unordered_float_sum): preceding-line suppression
    xs.iter().copied().collect::<HashMap<u64, f64>>().values().sum::<f64>() // lint:allow(hash_collection): trailing-form suppression
}

// `#[cfg(test)]`-gated hazards are exempt (tests may use wall clocks,
// hash maps, and prints), so `hybridflow lint` must stay silent on this
// file. Not compiled into any cargo target.

pub fn lib_code() -> u32 {
    7
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn hazards_are_fine_in_tests() {
        let mut m = HashMap::new();
        m.insert(1u32, 2u32);
        let t0 = std::time::Instant::now();
        let mut v = vec![2.0, 1.0];
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!("elapsed {:?} {:?} {:?}", t0.elapsed(), m, v);
    }
}

// String/comment traps: every hazard token below lives inside a string
// literal or a comment, so the lexer must hide it from the rules and
// `hybridflow lint` must stay silent. Not compiled into any target.

// A comment mentioning HashMap, std::time::Instant::now(), println!,
// thread::spawn, and a.partial_cmp(b).unwrap() changes nothing.

/* Block comments too: SystemTime::now() and .sum::<f64>() over a
   HashSet, /* nested: Instant::now() */ still nothing. */

pub const DOC: &str = "call partial_cmp(x).unwrap() or println! on a HashMap";
pub const RAW: &str = r#"std::time::Instant::now() and thread::spawn(|| {})"#;
pub const HASHY: &str = r##"raw with hashes: HashSet::new() and eprintln!("x")"##;
pub const TRICKY: &str = "escaped \" then SystemTime::now() and a \\ backslash";
pub const MULTI: &str = "line one mentions Instant::now()
line two mentions HashMap::new()";

pub fn lifetimes<'a>(x: &'a str) -> (&'a str, char) {
    let c = 'x';
    (x, c)
}

// Kernel-path cast traps (the fixture sits under `sim/`): integer-only
// `as` casts must stay silent even with floats elsewhere in the
// expression, and a genuinely float cast is suppressed with a justified
// allow. Not compiled into any cargo target.

pub fn widen(workers: usize) -> u64 {
    workers as u64
}

pub fn seeded(seed: u64, index: usize) -> u64 {
    seed ^ (index as u64).wrapping_mul(0x9E3779B97f4A7C15)
}

pub fn enumerated(n: usize) -> Vec<(f64, u32)> {
    (0..n as u32).map(|w| (0.0, w)).collect()
}

pub fn bucket(x: f64, n: usize) -> usize {
    // lint:allow(float_int_cast): fixture exercises a justified suppression
    (x * n as f64).floor() as usize
}

//! Cross-module integration tests (artifact-light: uses the trained-router
//! mirror when present, synthetic predictor otherwise).
//!
//! Covers: full-pipeline behavior orderings, failure injection (malformed
//! plans, budget exhaustion, degenerate queries), concurrency determinism,
//! and property tests over the pipeline-level invariants.

use hybridflow::baselines::{Cot, Direct, Dot, HybridLlm, Method};
use hybridflow::config::simparams::SimParams;
use hybridflow::models::SimExecutor;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::{PlannerProfile, SyntheticPlanner};
use hybridflow::planner::{PlanText, Planner};
use hybridflow::router::threshold::Threshold;
use hybridflow::router::{MirrorPredictor, RoutePolicy};
use hybridflow::scheduler::ScheduleConfig;
use hybridflow::testing::forall;
use hybridflow::util::rng::Rng;
use hybridflow::workload::{generate_queries, Benchmark, Query};
use std::sync::Arc;

fn predictor() -> Arc<MirrorPredictor> {
    let dir = hybridflow::config::default_artifacts_dir();
    MirrorPredictor::from_meta_file(&dir.join("router_meta.json"))
        .map(Arc::new)
        .unwrap_or_else(|_| Arc::new(MirrorPredictor::synthetic_for_tests()))
}

fn pipeline_with(policy: RoutePolicy) -> HybridFlowPipeline {
    let sp = SimParams::default();
    let mut cfg = PipelineConfig::paper_default(&sp);
    cfg.policy = policy;
    HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        predictor(),
        cfg,
    )
}

fn mean_of<F: FnMut(&Query, &mut Rng) -> f64>(
    bench: Benchmark,
    n: usize,
    seed: u64,
    mut f: F,
) -> f64 {
    let qs = generate_queries(bench, n, seed);
    let mut rng = Rng::new(seed ^ 0x5151);
    qs.iter().map(|q| f(q, &mut rng)).sum::<f64>() / n as f64
}

// ---------------------------------------------------------------------------
// Headline orderings (the paper's qualitative claims).
// ---------------------------------------------------------------------------

#[test]
fn hybridflow_beats_random_and_cloud_on_utility() {
    let sp = SimParams::default();
    let n = 400;
    let edge_acc = mean_of(Benchmark::Gpqa, n, 1, |q, rng| {
        f64::from(Cot::new(SimExecutor::paper_pair(), false).run(q, rng).correct)
    }) * 100.0;
    let edge_lat = mean_of(Benchmark::Gpqa, n, 1, |q, rng| {
        Cot::new(SimExecutor::paper_pair(), false).run(q, rng).latency
    });

    let utility = |policy: RoutePolicy| {
        let p = pipeline_with(policy);
        let qs = generate_queries(Benchmark::Gpqa, n, 2);
        let mut rng = Rng::new(99);
        let outs: Vec<_> = qs.iter().map(|q| p.run_query(q, &mut rng)).collect();
        let acc = outs.iter().filter(|o| o.correct).count() as f64 / n as f64 * 100.0;
        let lat = outs.iter().map(|o| o.latency).sum::<f64>() / n as f64;
        let api = outs.iter().map(|o| o.api_cost).sum::<f64>() / n as f64;
        hybridflow::router::utility::unified_utility(&sp, acc, edge_acc, lat, edge_lat, api)
            .unwrap_or(0.0)
    };

    let hf = utility(RoutePolicy::hybridflow(&sp));
    let random = utility(RoutePolicy::Random(0.45));
    let cloud = utility(RoutePolicy::AllCloud);
    assert!(hf > random + 0.05, "hf {hf} random {random}");
    assert!(hf > cloud + 0.05, "hf {hf} cloud {cloud}");
}

#[test]
fn dag_parallelism_beats_chain_latency() {
    let sp = SimParams::default();
    let dag = pipeline_with(RoutePolicy::hybridflow(&sp));
    let mut chain = pipeline_with(RoutePolicy::hybridflow(&sp));
    chain.config.schedule = ScheduleConfig { chain_mode: true, ..Default::default() };
    let n = 300;
    let lat_dag = mean_of(Benchmark::Gpqa, n, 3, |q, rng| dag.run_query(q, rng).latency);
    let lat_chain = mean_of(Benchmark::Gpqa, n, 3, |q, rng| chain.run_query(q, rng).latency);
    assert!(lat_dag < lat_chain, "dag {lat_dag} chain {lat_chain}");
}

#[test]
fn hybridflow_cheaper_than_cloud_with_competitive_accuracy() {
    let sp = SimParams::default();
    let hf = pipeline_with(RoutePolicy::hybridflow(&sp));
    let cloud = pipeline_with(RoutePolicy::AllCloud);
    let n = 400;
    let qs = generate_queries(Benchmark::Gpqa, n, 4);
    let mut r1 = Rng::new(11);
    let mut r2 = Rng::new(11);
    let hf_outs: Vec<_> = qs.iter().map(|q| hf.run_query(q, &mut r1)).collect();
    let cl_outs: Vec<_> = qs.iter().map(|q| cloud.run_query(q, &mut r2)).collect();
    let hf_acc = hf_outs.iter().filter(|o| o.correct).count() as f64 / n as f64;
    let cl_acc = cl_outs.iter().filter(|o| o.correct).count() as f64 / n as f64;
    let hf_api: f64 = hf_outs.iter().map(|o| o.api_cost).sum();
    let cl_api: f64 = cl_outs.iter().map(|o| o.api_cost).sum();
    assert!(hf_api < cl_api * 0.65, "api {hf_api} vs cloud {cl_api}");
    assert!(hf_acc > cl_acc - 0.08, "acc {hf_acc} vs cloud {cl_acc}");
    assert!(hf_acc > 0.35); // far above edge-only
}

#[test]
fn hybrid_baselines_sit_between_edge_and_cloud() {
    let n = 400;
    for bench in [Benchmark::Gpqa, Benchmark::MmluPro] {
        let acc = |m: &dyn Method, seed: u64| {
            mean_of(bench, n, seed, |q, rng| f64::from(m.run(q, rng).correct)) * 100.0
        };
        let edge = acc(&Cot::new(SimExecutor::paper_pair(), false), 5);
        let cloud = acc(&Cot::new(SimExecutor::paper_pair(), true), 5);
        let dot = acc(&Dot::paper_default(SimExecutor::paper_pair()), 5);
        let hllm = acc(&HybridLlm::paper_default(SimExecutor::paper_pair()), 5);
        assert!(dot > edge && dot < cloud + 3.0, "{bench:?} dot {dot} in ({edge}, {cloud})");
        assert!(hllm > edge - 3.0 && hllm < cloud + 3.0, "{bench:?} hllm {hllm}");
    }
}

// ---------------------------------------------------------------------------
// Failure injection.
// ---------------------------------------------------------------------------

/// Planner that always emits garbage: the pipeline must survive on the
/// chain fallback path for every query.
#[test]
fn survives_total_planner_failure() {
    struct BrokenPlanner;
    impl Planner for BrokenPlanner {
        fn plan_text(&self, _q: &Query, _rng: &mut Rng) -> PlanText {
            PlanText { xml: "<<<not xml>>>".into(), planning_latency: 1.0, plan_tokens: 5.0 }
        }
    }
    let q = &generate_queries(Benchmark::Gpqa, 1, 0)[0];
    let mut rng = Rng::new(0);
    let plan = BrokenPlanner.plan(q, 7, &mut rng);
    assert_eq!(plan.outcome, hybridflow::dag::RepairOutcome::Fallback);
    assert!(hybridflow::dag::validate(&plan.dag, 7).is_valid());
}

/// Degenerate queries (difficulty 0 and 1, tiny/huge prompts) must not panic.
#[test]
fn degenerate_queries_run() {
    let sp = SimParams::default();
    let p = pipeline_with(RoutePolicy::hybridflow(&sp));
    let mut rng = Rng::new(0);
    for difficulty in [0.0, 1.0] {
        for tokens in [1.0, 5000.0] {
            let q = Query {
                id: 0,
                benchmark: Benchmark::Gpqa,
                domain: 1,
                difficulty,
                query_tokens: tokens,
                tok_mult: 1.0,
            };
            let out = p.run_query(&q, &mut rng);
            assert!(out.latency.is_finite() && out.latency > 0.0);
        }
    }
}

/// Budget exhaustion: with an absurdly tight latency/API budget the Eq-27
/// router must converge to (almost) pure edge execution.
#[test]
fn budget_exhaustion_forces_edge() {
    let tight = Threshold::ResourcePressure(hybridflow::router::threshold::ResourcePressure {
        tau0: 0.5,
        k_max: 1e-6,
        l_max: 1e-3,
    });
    let p = pipeline_with(RoutePolicy::Learned { threshold: tight, calibrate: false });
    let off = mean_of(Benchmark::Gpqa, 100, 6, |q, rng| p.run_query(q, rng).offload_rate);
    assert!(off < 0.05, "offload under exhausted budget: {off}");
}

/// Predictors pinned at 0 (never offload) and 1 (always offload) must still
/// produce valid executions — routing-layer robustness to a broken model.
#[test]
fn extreme_predictors_are_safe() {
    struct Const(f64);
    impl hybridflow::router::predictor::UtilityPredictor for Const {
        fn predict(&self, feats: &[hybridflow::embed::Features], _c: f64) -> Vec<f64> {
            vec![self.0; feats.len()]
        }
        fn backend(&self) -> &'static str {
            "const"
        }
    }
    let sp = SimParams::default();
    for v in [0.0, 1.0] {
        let p = HybridFlowPipeline::with_predictor(
            SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            Arc::new(Const(v)),
            PipelineConfig::paper_default(&sp),
        );
        let out = mean_of(Benchmark::Gpqa, 50, 7, |q, rng| p.run_query(q, rng).offload_rate);
        if v == 0.0 {
            assert_eq!(out, 0.0);
        } else {
            assert!(out > 0.9);
        }
    }
}

// ---------------------------------------------------------------------------
// Properties over the pipeline.
// ---------------------------------------------------------------------------

#[test]
fn prop_pipeline_invariants() {
    let sp = SimParams::default();
    let p = pipeline_with(RoutePolicy::hybridflow(&sp));
    forall("pipeline invariants", 60, move |g| {
        let bench = *g.rng.choice(&Benchmark::ALL);
        let seed = g.rng.next_u64() % 1000;
        let q = &generate_queries(bench, 1, seed)[0];
        let mut rng = Rng::new(seed);
        let (exec, _) = p.run_query_traced(q, &mut rng);
        // Invariants: events complete; budget consistent with API spend;
        // offload rate consistent with events; time monotone per event.
        let cloud_events = exec.events.iter().filter(|e| e.cloud).count();
        let api_from_events: f64 = exec.events.iter().map(|e| e.api_cost).sum();
        exec.latency > 0.0
            && exec.events.len() == exec.n_subtasks
            && (exec.api_cost - api_from_events).abs() < 1e-9
            && (exec.offload_rate - cloud_events as f64 / exec.n_subtasks as f64).abs() < 1e-9
            && exec.events.iter().all(|e| e.finish >= e.start)
    });
}

#[test]
fn prop_planner_output_always_executable() {
    forall("planner plans always executable", 100, |g| {
        let profile = match g.usize_in(0..4) {
            0 => PlannerProfile::paper_main(),
            1 => PlannerProfile::base_llama(),
            2 => PlannerProfile::sft_llama(),
            _ => PlannerProfile::frontier_reference(),
        };
        let planner = SyntheticPlanner::new(profile);
        let bench = *g.rng.choice(&Benchmark::ALL);
        let q = &generate_queries(bench, 1, g.rng.next_u64() % 500)[0];
        let mut rng = Rng::new(g.rng.next_u64());
        let plan = planner.plan(q, 7, &mut rng);
        hybridflow::dag::validate(&plan.dag, 7).is_valid() && plan.dag.len() >= 2
    });
}

#[test]
fn concurrent_serving_is_deterministic() {
    let sp = SimParams::default();
    let qs = generate_queries(Benchmark::MmluPro, 80, 9);
    let report1 = hybridflow::server::serve(
        Arc::new(pipeline_with(RoutePolicy::hybridflow(&sp))),
        qs.clone(),
        2,
        1234,
    );
    let report2 = hybridflow::server::serve(
        Arc::new(pipeline_with(RoutePolicy::hybridflow(&sp))),
        qs,
        7,
        1234,
    );
    assert_eq!(report1.accuracy_pct, report2.accuracy_pct);
    assert_eq!(report1.total_api_cost, report2.total_api_cost);
}

#[test]
fn direct_cheaper_than_cot_both_sides() {
    let n = 300;
    for cloud in [false, true] {
        let d = mean_of(Benchmark::Gpqa, n, 10, |q, rng| {
            Direct::new(SimExecutor::paper_pair(), cloud).run(q, rng).latency
        });
        let c = mean_of(Benchmark::Gpqa, n, 10, |q, rng| {
            Cot::new(SimExecutor::paper_pair(), cloud).run(q, rng).latency
        });
        assert!(d < c, "cloud={cloud}: direct {d} cot {c}");
    }
}

#[test]
fn replay_backend_reproduces_recorded_schedule() {
    // Record a full scheduled execution through the Backend seam, then
    // re-serve the tape with ReplayBackend: the schedule (starts, finishes,
    // makespan), costs, and per-subtask correctness must reproduce exactly,
    // even though replay consumes no RNG.
    use hybridflow::engine::{Backend, RecordingBackend};
    use hybridflow::router::RouterState;
    use hybridflow::scheduler::execute_query;
    use hybridflow::workload::sample_latents;

    let recorder = RecordingBackend::new(SimExecutor::paper_pair());
    let planner = SyntheticPlanner::paper_main();
    let q = generate_queries(Benchmark::Gpqa, 1, 5).pop().unwrap();
    let mut rng = Rng::new(77);
    let plan = planner.plan(&q, 7, &mut rng);
    let latents = sample_latents(&plan.dag, &q, recorder.sp(), &mut rng);
    let pred = MirrorPredictor::synthetic_for_tests();

    let run = |backend: &dyn Backend| {
        let mut router = RouterState::new(RoutePolicy::AllCloud);
        let mut rng = Rng::new(9);
        execute_query(
            &plan.dag,
            &latents,
            &q,
            backend,
            &pred,
            &mut router,
            2.0,
            &ScheduleConfig::default(),
            &mut rng,
        )
    };

    let original = run(&recorder);
    assert_eq!(recorder.records().len(), plan.dag.len());
    let replay = recorder.into_replay();
    let replayed = run(&replay);
    assert_eq!(replay.remaining(), 0, "replay must consume the whole tape");

    // Accuracy verdict replays from the tape (not re-drawn from the RNG
    // stream, which sits at a different position during replay).
    assert_eq!(original.correct, replayed.correct);
    assert_eq!(original.latency, replayed.latency);
    assert_eq!(original.api_cost, replayed.api_cost);
    assert_eq!(original.offload_rate, replayed.offload_rate);
    assert_eq!(original.events.len(), replayed.events.len());
    for (a, b) in original.events.iter().zip(&replayed.events) {
        assert_eq!(a.node, b.node);
        assert_eq!(a.start, b.start);
        assert_eq!(a.finish, b.finish);
        assert_eq!(a.api_cost, b.api_cost);
        assert_eq!(a.correct, b.correct);
        assert_eq!(a.cloud, b.cloud);
    }
}

//! Cross-query result-cache invariant suite (PR 3 satellite):
//!
//! * **capacity** — entries never exceed the per-partition cap under
//!   random insert/lookup churn, for every eviction policy;
//! * **bit-identity** — a hit replays the *first* execution's record
//!   bit-for-bit, regardless of later insert attempts under the same key;
//! * **tenant isolation** — tenant A never reads tenant B's partition
//!   unless the shared global tier is enabled;
//! * **end-to-end** — a cached pipeline serving a repeated query stream
//!   spends strictly less than the uncached pipeline, deterministically.

use hybridflow::cache::{CachePolicyKind, CachedBackend, CachedResult, Fingerprint, SubtaskCache};
use hybridflow::config::simparams::SimParams;
use hybridflow::engine::Backend;
use hybridflow::models::{ExecRecord, SimExecutor};
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::router::{MirrorPredictor, RoutePolicy};
use hybridflow::testing::forall;
use hybridflow::util::rng::Rng;
use hybridflow::workload::{generate_queries, Benchmark, SubtaskLatent};
use std::sync::Arc;

fn record(g_seed: u64) -> ExecRecord {
    // Deterministic but irregular float payloads (bit-identity fodder).
    let mut rng = Rng::new(g_seed);
    ExecRecord {
        correct: rng.bernoulli(0.5),
        latency: rng.lognormal(0.3, 1.1),
        api_cost: rng.f64() * 0.01,
        in_tokens: rng.lognormal(5.0, 0.7),
        out_tokens: rng.lognormal(4.5, 0.8),
    }
}

// ---------------------------------------------------------------------------
// Capacity under churn.
// ---------------------------------------------------------------------------

#[test]
fn prop_entries_never_exceed_capacity_under_churn() {
    forall("partition sizes <= capacity under random churn", 40, |g| {
        let capacity = g.usize_in(1..24);
        let kind = match g.usize_in(0..3) {
            0 => CachePolicyKind::Lru,
            1 => CachePolicyKind::Lfu,
            _ => CachePolicyKind::Ttl(g.f64_in(0.5..20.0)),
        };
        let shared = g.bool();
        let cache = SubtaskCache::new(capacity, kind);
        let cache = if shared { cache.with_shared_tier() } else { cache };
        let tenants = g.usize_in(1..4);
        let key_space = g.usize_in(1..80) as u64;
        let ops = g.usize_in(50..300);
        let mut now = 0.0;
        for _ in 0..ops {
            now += g.f64_in(0.0..2.0);
            let tenant = g.usize_in(0..tenants);
            let key = Fingerprint(g.rng.next_u64() % key_space);
            if g.bool() {
                cache.insert(
                    tenant,
                    key,
                    CachedResult { cloud: g.bool(), rec: record(key.0 ^ 7) },
                    now,
                    now,
                );
            } else {
                let _ = cache.lookup(tenant, key, now);
            }
            for t in 0..tenants {
                if cache.len(t) > capacity {
                    return false;
                }
            }
            if cache.shared_len() > capacity {
                return false;
            }
        }
        // Counter sanity: hits never exceed lookups; rate stays in [0, 1].
        let s = cache.stats();
        s.hits <= s.lookups && (0.0..=1.0).contains(&s.hit_rate())
    });
}

// ---------------------------------------------------------------------------
// Bit-identity.
// ---------------------------------------------------------------------------

#[test]
fn prop_hits_replay_first_execution_bit_identically() {
    forall("hit == first stored record, bit for bit", 40, |g| {
        let kind = if g.bool() { CachePolicyKind::Lru } else { CachePolicyKind::Lfu };
        let cache = SubtaskCache::new(16, kind);
        let key = Fingerprint(g.rng.next_u64());
        let first = CachedResult { cloud: g.bool(), rec: record(g.rng.next_u64()) };
        cache.insert(0, key, first, 0.0, 0.0);
        // Later inserts under the same key must not clobber the stored
        // record (hits stay identical to the FIRST execution).
        for i in 0..g.usize_in(0..4) {
            let t = i as f64 + 1.0;
            cache.insert(0, key, CachedResult { cloud: !first.cloud, rec: record(i as u64) }, t, t);
        }
        match cache.lookup(0, key, 10.0) {
            None => false,
            Some(hit) => {
                hit.cloud == first.cloud
                    && hit.rec.correct == first.rec.correct
                    && hit.rec.latency.to_bits() == first.rec.latency.to_bits()
                    && hit.rec.api_cost.to_bits() == first.rec.api_cost.to_bits()
                    && hit.rec.in_tokens.to_bits() == first.rec.in_tokens.to_bits()
                    && hit.rec.out_tokens.to_bits() == first.rec.out_tokens.to_bits()
            }
        }
    });
}

#[test]
fn cached_backend_hits_are_bit_identical_and_rng_free() {
    let backend = CachedBackend::new(SimExecutor::paper_pair(), 128, CachePolicyKind::Lru);
    let l = SubtaskLatent { difficulty: 0.55, criticality: 0.6, out_tokens: 110.0 };
    let mut rng = Rng::new(17);
    let first = backend.execute_subtask(2, &l, 240.0, true, &mut rng);
    // A fresh, differently-seeded stream must not change the replay.
    let mut other = Rng::new(99999);
    let probe = other.clone();
    let again = backend.execute_subtask(2, &l, 240.0, true, &mut other);
    assert_eq!(first.latency.to_bits(), again.latency.to_bits());
    assert_eq!(first.api_cost.to_bits(), again.api_cost.to_bits());
    assert_eq!(first.out_tokens.to_bits(), again.out_tokens.to_bits());
    assert_eq!(first.correct, again.correct);
    let mut untouched = probe;
    assert_eq!(
        other.next_u64(),
        untouched.next_u64(),
        "a hit must consume zero RNG from the caller's stream"
    );
}

// ---------------------------------------------------------------------------
// Tenant isolation.
// ---------------------------------------------------------------------------

#[test]
fn prop_tenant_partitions_are_isolated_without_shared_tier() {
    forall("tenant A never reads tenant B's entries", 40, |g| {
        let shared = g.bool();
        let cache = SubtaskCache::new(32, CachePolicyKind::Lru);
        let cache = if shared { cache.with_shared_tier() } else { cache };
        let writer = g.usize_in(0..3);
        let reader = (writer + g.usize_in(1..3)) % 3; // always != writer
        let key = Fingerprint(g.rng.next_u64());
        cache.insert(writer, key, CachedResult { cloud: true, rec: record(1) }, 0.0, 0.0);
        let own = cache.lookup(writer, key, 1.0).is_some();
        let cross = cache.lookup(reader, key, 1.0).is_some();
        // Own partition always hits; cross-tenant hits iff shared tier.
        own && (cross == shared)
    });
}

// ---------------------------------------------------------------------------
// End-to-end: cached pipeline on a repeated query stream.
// ---------------------------------------------------------------------------

fn pipeline_with_cache(capacity: usize) -> HybridFlowPipeline {
    let sp = SimParams::default();
    let mut cfg = PipelineConfig::paper_default(&sp);
    cfg.policy = RoutePolicy::AllCloud;
    if capacity > 0 {
        cfg.schedule.cache = Some(Arc::new(SubtaskCache::new(capacity, CachePolicyKind::Lru)));
    }
    HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        Arc::new(MirrorPredictor::synthetic_for_tests()),
        cfg,
    )
}

#[test]
fn cached_pipeline_cuts_spend_on_repeated_queries() {
    // One query content served 8 times: the cached pipeline pays full
    // price once and serves overlap from the cache afterwards.
    let q = generate_queries(Benchmark::Gpqa, 1, 23).pop().unwrap();
    let total_cost = |capacity: usize| -> f64 {
        let p = pipeline_with_cache(capacity);
        let mut rng = Rng::new(5);
        (0..8).map(|_| p.run_query(&q, &mut rng).api_cost).sum()
    };
    let uncached = total_cost(0);
    let cached = total_cost(512);
    assert!(
        cached < uncached,
        "cached spend {cached} must undercut uncached {uncached}"
    );
}

#[test]
fn cached_pipeline_is_deterministic() {
    let q = generate_queries(Benchmark::MmluPro, 1, 31).pop().unwrap();
    let run = || -> Vec<(bool, f64, f64)> {
        let p = pipeline_with_cache(64);
        let mut rng = Rng::new(9);
        (0..6)
            .map(|_| {
                let o = p.run_query(&q, &mut rng);
                (o.correct, o.latency, o.api_cost)
            })
            .collect()
    };
    assert_eq!(run(), run(), "cached single-thread serving must be reproducible");
}

//! Observability integration suite:
//!
//! * **golden artifacts** — the instrumented `fleet_sim` preset exports
//!   byte-stable span (Chrome trace-event JSON) and metrics (JSONL)
//!   artifacts, pinned per shard count by checked-in golden files and
//!   required to be byte-identical across reruns and worker-thread
//!   counts (pid is the shard id, so the shard-1 and shard-4 artifacts
//!   legitimately differ from each other — each is pinned separately);
//! * **format validity** — the trace parses with `util::json`, carries
//!   `thread_name` metadata before time-sorted `ph: "X"` complete events
//!   with integral `ts`/`dur`/`pid`/`tid`, and pretty-printing the parse
//!   is a byte fixpoint; every metrics line is a standalone JSON row
//!   with the full column set and non-decreasing `t`;
//! * **read-only contract** — turning observability off reproduces the
//!   default preset's trace and report byte-for-byte (only the
//!   instrumented run carries the `critical_path` section);
//! * **critical-path arithmetic** — per-query busy time plus slack
//!   reconstructs the makespan, and the report summary equals its
//!   recomputation from the path set.

use hybridflow::obs::{ObserveConfig, CACHE_LANE, CLOUD_LANE_BASE};
use hybridflow::router::MirrorPredictor;
use hybridflow::scenario::presets::{self, FleetSimKnobs};
use hybridflow::scenario::{ScenarioSpec, Session};
use hybridflow::util::json::Json;
use hybridflow::workload::Benchmark;
use std::path::PathBuf;
use std::sync::Arc;

fn observed_spec(shards: usize) -> ScenarioSpec {
    let knobs = FleetSimKnobs {
        observe: Some(ObserveConfig { spans: true, metrics: true, metrics_interval: 1.0 }),
        ..Default::default()
    };
    let mut spec = presets::fleet_sim(Benchmark::Gpqa, 24, 0.8, 11, &knobs);
    spec.topology.shards = shards;
    spec
}

fn session(shards: usize) -> Session {
    observed_spec(shards).build(Arc::new(MirrorPredictor::synthetic_for_tests())).unwrap()
}

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden").join(name)
}

/// Compare against the pinned golden file, bootstrapping it on first run
/// (the `rust/tests/golden/fleet_trace.txt` convention). Regenerate after
/// an intentional engine change by deleting the file and rerunning.
fn pin(name: &str, bytes: &str) {
    let path = golden_path(name);
    if path.exists() {
        let pinned = std::fs::read_to_string(&path).expect("read golden file");
        assert_eq!(
            bytes,
            pinned,
            "{} diverged — if the change is intentional, delete the file and rerun this test \
             to regenerate",
            path.display()
        );
    } else {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).expect("create golden dir");
        }
        std::fs::write(&path, bytes).expect("write golden file");
        eprintln!("[obs golden] bootstrapped {}", path.display());
    }
}

#[test]
fn golden_artifacts_pinned_across_shards_and_threads() {
    for shards in [1usize, 4] {
        let s = session(shards);
        let base = s.run_with_threads(1);
        let obs = base.obs.as_ref().expect("observe on");
        assert_eq!(obs.unclosed_spans, 0, "every opened span closed");
        assert!(obs.spans.len() >= 24, "each query contributes at least one span");
        let trace = obs.chrome_trace_text();
        let metrics = obs.metrics_jsonl();
        for threads in [1usize, 4] {
            let r = s.run_with_threads(threads);
            let o = r.obs.as_ref().expect("observe on");
            assert_eq!(
                o.chrome_trace_text(),
                trace,
                "shards={shards} threads={threads}: trace artifact bytes"
            );
            assert_eq!(
                o.metrics_jsonl(),
                metrics,
                "shards={shards} threads={threads}: metrics artifact bytes"
            );
        }
        pin(&format!("obs_fleet_trace_s{shards}.json"), &trace);
        pin(&format!("obs_fleet_metrics_s{shards}.jsonl"), &metrics);
    }
}

#[test]
fn chrome_trace_is_valid_trace_event_json() {
    let report = session(4).run_with_threads(2);
    let text = report.obs.as_ref().unwrap().chrome_trace_text();
    let j = Json::parse(&text).expect("trace-event document parses");
    // Canonical JSON: parse → pretty-print is a byte fixpoint.
    let mut rendered = j.to_string_pretty();
    rendered.push('\n');
    assert_eq!(rendered, text, "exported trace is canonical JSON");
    assert_eq!(j.get("displayTimeUnit"), Some(&Json::Str("ms".into())));
    let events = match j.get("traceEvents") {
        Some(Json::Arr(events)) => events,
        other => panic!("traceEvents must be an array, got {other:?}"),
    };
    let mut seen_x = false;
    let mut last_ts = f64::NEG_INFINITY;
    for e in events {
        match e.get("ph") {
            Some(Json::Str(ph)) if ph == "M" => {
                assert!(!seen_x, "thread_name metadata precedes complete events");
                assert_eq!(e.get("name"), Some(&Json::Str("thread_name".into())));
                let label = match e.path(&["args", "name"]) {
                    Some(Json::Str(s)) => s.clone(),
                    other => panic!("lane label: {other:?}"),
                };
                assert!(
                    label == "cache"
                        || label.starts_with("edge-")
                        || label.starts_with("cloud-"),
                    "lane label {label}"
                );
            }
            Some(Json::Str(ph)) if ph == "X" => {
                seen_x = true;
                for key in ["ts", "dur", "pid", "tid"] {
                    match e.get(key) {
                        Some(Json::Num(x)) => assert!(
                            x.is_finite() && *x >= 0.0 && x.fract() == 0.0,
                            "{key} must be a non-negative integer, got {x}"
                        ),
                        other => panic!("complete event lacks numeric {key}: {other:?}"),
                    }
                }
                let ts = e.get("ts").and_then(Json::as_f64).unwrap();
                assert!(ts >= last_ts, "complete events sorted by dispatch time");
                last_ts = ts;
                let pid = e.get("pid").and_then(Json::as_f64).unwrap();
                assert!(pid < 4.0, "pid is the shard id");
                let tid = e.get("tid").and_then(Json::as_f64).unwrap() as usize;
                assert!(
                    tid == CACHE_LANE || (1..CLOUD_LANE_BASE + 1_000).contains(&tid),
                    "tid {tid} outside the lane scheme"
                );
            }
            other => panic!("unexpected ph: {other:?}"),
        }
    }
    assert!(seen_x, "trace carries complete events");
}

#[test]
fn metrics_jsonl_rows_parse_with_full_columns_and_monotone_time() {
    let report = session(4).run_with_threads(4);
    let text = report.obs.as_ref().unwrap().metrics_jsonl();
    let mut last_t = f64::NEG_INFINITY;
    let mut rows = 0usize;
    for line in text.lines() {
        let row = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL row {line}: {e}"));
        let t = row.get("t").and_then(Json::as_f64).expect("t column");
        assert!(t >= last_t, "snapshot times regress: {t} after {last_t}");
        last_t = t;
        for key in [
            "admission_backlog", "cache_hit_rate", "cache_hits", "cache_lookups", "cloud_busy",
            "completed", "edge_busy", "global_spent", "latency_mean", "latency_p50",
            "latency_p99", "ready_depth", "shard",
        ] {
            let x = row.get(key).and_then(Json::as_f64);
            assert!(matches!(x, Some(v) if v.is_finite()), "row lacks finite {key}: {line}");
        }
        let shard = row.get("shard").and_then(Json::as_f64).unwrap();
        assert!(shard < 4.0, "shard column within the shard count");
        rows += 1;
    }
    assert!(rows > 0, "metrics series is non-empty");
}

#[test]
fn observe_off_is_byte_identical_to_default_preset() {
    let on = session(1).run();
    let off_spec = presets::fleet_sim(Benchmark::Gpqa, 24, 0.8, 11, &FleetSimKnobs::default());
    let off = off_spec.build(Arc::new(MirrorPredictor::synthetic_for_tests())).unwrap().run();
    assert!(off.obs.is_none(), "observe off leaves no artifacts");
    assert!(off.critical_path.is_none());
    assert_eq!(on.trace_text(), off.trace_text(), "observability is read-only");
    let mut on_json = on.to_json();
    if let Json::Obj(o) = &mut on_json {
        o.remove("critical_path");
    }
    assert_eq!(
        on_json.to_string_pretty(),
        off.to_json().to_string_pretty(),
        "reports agree up to the critical_path section"
    );
    assert!(on.critical_path.is_some(), "instrumented run surfaces the critical path");
    assert!(on.render().contains("critical path:"));
}

#[test]
fn critical_path_arithmetic_is_consistent() {
    let report = session(1).run();
    let obs = report.obs.as_ref().unwrap();
    assert!(!obs.paths.is_empty());
    for p in &obs.paths {
        assert_eq!(p.nodes.len(), p.slacks.len(), "one slack per path node");
        assert!(!p.nodes.is_empty());
        assert!(p.path_latency >= 0.0 && p.makespan >= 0.0);
        let slack: f64 = p.slacks.iter().sum();
        assert!(
            (p.path_latency + slack - p.makespan).abs() < 1e-6,
            "q{}: busy {} + slack {slack} != makespan {}",
            p.q,
            p.path_latency,
            p.makespan
        );
    }
    let summary = report.critical_path.as_ref().unwrap();
    let recomputed =
        hybridflow::obs::CriticalPathSummary::from_paths(&obs.paths).expect("paths exist");
    assert_eq!(summary, &recomputed, "report summary equals its recomputation");
    assert_eq!(summary.queries, obs.paths.len());
}

# Convenience targets. `make verify` is the tier-1 gate (build + tests,
# golden-trace + scenario tests included, + enforced fmt check).

.PHONY: verify build test fmt artifacts

verify:
	./scripts/verify.sh

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt

# Lower the python-authored router/edge-LM computations to HLO text for
# the PJRT runtime (requires the python environment; see python/compile).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

//! Vendored minimal subset of the `anyhow` error-handling API.
//!
//! The build environment has no registry access, so the crate is vendored
//! in-tree. Only the surface the repository actually uses is provided:
//! [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros.
//! `Error` intentionally does **not** implement `std::error::Error` — just
//! like the real crate — so the blanket `From<E: std::error::Error>`
//! conversion used by `?` stays coherent.

use std::fmt;

/// A string-backed error value. The source chain of the converted error is
/// flattened into the message at conversion time.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut msg = err.to_string();
        let mut source = err.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Error { msg }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    fn fails(flag: bool) -> super::Result<u32> {
        super::ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    fn bails() -> super::Result<()> {
        super::bail!("always fails with code {}", 3);
    }

    #[test]
    fn display_and_macros() {
        let e = super::anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        assert_eq!(fails(true).unwrap(), 7);
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
        assert!(bails().unwrap_err().to_string().contains("code 3"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> super::Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}

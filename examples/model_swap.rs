//! Model-pair swap scenario (paper App. D.2 / Table 8): replace the
//! Llama3.2-3B + GPT-4.1 pair with Qwen2.5-7B + DeepSeek-V3 *without
//! touching anything else* — same planner, same routing logic, same budget
//! machinery — and compare the edge-cloud methods under the new pair.
//!
//! ```sh
//! cargo run --release --example model_swap -- [--n 100]
//! ```

use hybridflow::baselines::{Cot, Dot, HybridLlm, Method};
use hybridflow::bench::Table;
use hybridflow::config::simparams::SimParams;
use hybridflow::models::SimExecutor;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::router::{MirrorPredictor, RoutePolicy};
use hybridflow::util::cli::Args;
use hybridflow::util::rng::Rng;
use hybridflow::workload::{generate_queries, Benchmark};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize_or("n", 100)?;
    let artifacts = hybridflow::config::default_artifacts_dir();
    let predictor =
        Arc::new(MirrorPredictor::from_meta_file(&artifacts.join("router_meta.json"))?);
    let sp = SimParams::default();

    for (pair_name, make) in [
        ("main pair: Llama3.2-3B edge + GPT-4.1 cloud", SimExecutor::paper_pair as fn() -> SimExecutor),
        ("swap pair: Qwen2.5-7B edge + DeepSeek-V3 cloud", SimExecutor::swap_pair as fn() -> SimExecutor),
    ] {
        let hf = HybridFlowPipeline::with_predictor(
            make(),
            SyntheticPlanner::paper_main(),
            predictor.clone(),
            PipelineConfig::paper_default(&sp),
        );
        let methods: Vec<(String, Box<dyn Fn(&hybridflow::workload::Query, &mut Rng) -> hybridflow::metrics::QueryOutcome>)> = vec![
            ("All-Edge CoT".into(), {
                let m = Cot::new(make(), false);
                Box::new(move |q, rng| m.run(q, rng))
            }),
            ("All-Cloud CoT".into(), {
                let m = Cot::new(make(), true);
                Box::new(move |q, rng| m.run(q, rng))
            }),
            ("HybridLLM".into(), {
                let m = HybridLlm::paper_default(make());
                Box::new(move |q, rng| m.run(q, rng))
            }),
            ("DoT".into(), {
                let m = Dot::paper_default(make());
                Box::new(move |q, rng| m.run(q, rng))
            }),
            ("HybridFlow".into(), Box::new(move |q, rng| hf.run_query(q, rng))),
        ];

        let mut t = Table::new(
            &format!("GPQA, {pair_name}"),
            &["Method", "Acc (%)", "API Cost (1e-3 $)", "Latency (s)"],
        );
        for (name, run) in &methods {
            let mut rng = Rng::new(5);
            let queries = generate_queries(Benchmark::Gpqa, n, 5);
            let mut correct = 0usize;
            let (mut lat, mut api) = (0.0, 0.0);
            for q in &queries {
                let out = run(q, &mut rng);
                correct += usize::from(out.correct);
                lat += out.latency;
                api += out.api_cost;
            }
            let nf = n as f64;
            t.row(vec![
                name.clone(),
                format!("{:.1}", correct as f64 / nf * 100.0),
                if api == 0.0 { "NA".into() } else { format!("{:.2}", api / nf * 1e3) },
                format!("{:.2}", lat / nf),
            ]);
        }
        t.print();
        println!();
    }
    println!("Expected shape (paper Table 8): HybridFlow keeps the best cost/latency/");
    println!("accuracy trade-off under the swapped pair with no re-engineering.");
    Ok(())
}

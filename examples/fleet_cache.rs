//! Result-cache fleet driver on the declarative Scenario API: a
//! Zipf-popularity workload (a few prototype queries dominate the arrival
//! stream) served with the cross-query subtask cache swept across
//! capacities, showing hit rate climbing, transmitted cloud tokens
//! falling, and the sojourn distribution tightening — then a determinism
//! check (two cached runs must produce byte-identical event traces).
//!
//! The scenario itself is `scenario::presets::fleet_cache` (shipped as
//! `scenarios/fleet_cache.json`), the same spec the `fleet_cache`
//! experiment runs, so this driver and the experiment table can never
//! drift apart.
//!
//! ```sh
//! cargo run --release --example fleet_cache -- \
//!     [--benchmark gpqa] [--n 60] [--rate 0.5] \
//!     [--zipf 1.1] [--distinct 8] [--policy lru] [--seed 11]
//! ```

use hybridflow::cache::CachePolicyKind;
use hybridflow::eval::experiments::fleet_cloud_tokens;
use hybridflow::router::{MirrorPredictor, UtilityPredictor};
use hybridflow::scenario::presets::{self, FleetCacheKnobs};
use hybridflow::scenario::Report;
use hybridflow::util::cli::Args;
use hybridflow::workload::Benchmark;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let bench = Benchmark::parse(args.get_or("benchmark", "gpqa"))
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark"))?;
    let n = args.get_usize_or("n", 60)?;
    let rate = args.get_f64_or("rate", 0.5)?;
    let zipf_exponent = args.get_f64_or("zipf", 1.1)?;
    let distinct = args.get_usize_or("distinct", 8)?.max(1);
    let policy = CachePolicyKind::parse(args.get_or("policy", "lru"))
        .ok_or_else(|| anyhow::anyhow!("unknown cache policy (lru|lfu|ttl[:secs])"))?;
    let seed = args.get_u64_or("seed", 11)?;

    let artifacts = hybridflow::config::default_artifacts_dir();
    let predictor: Arc<dyn UtilityPredictor> =
        match MirrorPredictor::from_meta_file(&artifacts.join("router_meta.json")) {
            Ok(p) => Arc::new(p),
            Err(_) => Arc::new(MirrorPredictor::synthetic_for_tests()),
        };

    let run = |capacity: usize| -> Report {
        let knobs = FleetCacheKnobs {
            capacity,
            policy,
            zipf_exponent,
            zipf_distinct: distinct,
            record_trace: true,
            ..Default::default()
        };
        presets::fleet_cache(bench, n, rate, seed, &knobs)
            .build(Arc::clone(&predictor))
            .expect("preset spec is valid")
            .run()
    };

    println!(
        "fleet_cache: {n} x {} queries, {distinct} zipf(s={zipf_exponent}) prototypes, \
         poisson {rate} q/s, policy {}, seed {seed}\n",
        bench.display(),
        policy.label(),
    );

    let acc = |r: &Report| {
        r.results.iter().filter(|q| q.exec.correct).count() as f64
            / r.results.len().max(1) as f64
            * 100.0
    };

    println!(
        "{:>8}  {:>9}  {:>12}  {:>12}  {:>10}  {:>8}  {:>8}  {:>7}",
        "capacity", "hit rate", "cloud toks", "toks saved", "C_API", "p50", "p95", "acc"
    );
    let mut cached_run: Option<Report> = None;
    for capacity in [0usize, 16, 64, 256] {
        let report = run(capacity);
        let (hit_rate, saved) = report
            .cache
            .as_ref()
            .map_or((0.0, 0.0), |c| (c.hit_rate() * 100.0, c.tokens_saved));
        println!(
            "{:>8}  {:>8.1}%  {:>12.0}  {:>12.0}  {:>10.4}  {:>7.2}s  {:>7.2}s  {:>6.2}%",
            if capacity == 0 { "off".into() } else { capacity.to_string() },
            hit_rate,
            fleet_cloud_tokens(&report),
            saved,
            report.total_api_cost,
            report.sojourn.p50,
            report.sojourn.p95,
            acc(&report),
        );
        if capacity == 256 {
            cached_run = Some(report);
        }
    }

    // Determinism: a repeat of the largest cached run must reproduce its
    // event trace byte-for-byte (the cache resets cold at each run start).
    let reference = cached_run.expect("capacity sweep ran");
    let again = run(256);
    anyhow::ensure!(
        again.trace_text() == reference.trace_text(),
        "determinism violated: cached run is not reproducible"
    );
    let stats = reference.cache.as_ref().expect("cache stats");
    println!(
        "\ncache @256: hit rate {:.1}% ({}/{} lookups, {} shared-tier), \
         {} evicted, {} expired",
        stats.hit_rate() * 100.0,
        stats.hits,
        stats.lookups,
        stats.shared_hits,
        stats.evictions,
        stats.expirations,
    );
    println!("determinism verified: cached rerun produced an identical event trace");
    Ok(())
}

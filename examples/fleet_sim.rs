//! Fleet simulation driver: a seeded multi-tenant workload on shared
//! edge/cloud pools, run **twice** to prove end-to-end determinism (the
//! two event traces must match byte-for-byte).
//!
//! ```sh
//! cargo run --release --example fleet_sim -- \
//!     [--benchmark gpqa] [--n 60] [--rate 0.5] [--tenants 3] \
//!     [--edge-workers 8] [--cloud-workers 16] [--admission 64] \
//!     [--tenant-cap 0.02] [--seed 11] [--trace]
//! ```

use hybridflow::budget::TenantPool;
use hybridflow::config::simparams::SimParams;
use hybridflow::models::SimExecutor;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::router::{MirrorPredictor, RoutePolicy};
use hybridflow::scheduler::fleet::FleetConfig;
use hybridflow::server::serve_fleet;
use hybridflow::util::cli::Args;
use hybridflow::workload::trace::ArrivalProcess;
use hybridflow::workload::Benchmark;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let bench = Benchmark::parse(args.get_or("benchmark", "gpqa"))
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark"))?;
    let n = args.get_usize_or("n", 60)?;
    let rate = args.get_f64_or("rate", 0.5)?;
    let n_tenants = args.get_usize_or("tenants", 3)?.max(1);
    let edge_workers = args.get_usize_or("edge-workers", 8)?;
    let cloud_workers = args.get_usize_or("cloud-workers", 16)?;
    let admission = args.get_usize_or("admission", 64)?;
    let tenant_cap = args.get_f64_or("tenant-cap", f64::INFINITY)?;
    let seed = args.get_u64_or("seed", 11)?;

    let sp = SimParams::default();
    let mut pcfg = PipelineConfig::paper_default(&sp);
    pcfg.policy = RoutePolicy::hybridflow(&sp);
    pcfg.schedule.edge_workers = edge_workers;
    pcfg.schedule.cloud_workers = cloud_workers;
    let artifacts = hybridflow::config::default_artifacts_dir();
    let predictor = MirrorPredictor::from_meta_file(&artifacts.join("router_meta.json"))
        .map(Arc::new)
        .unwrap_or_else(|_| Arc::new(MirrorPredictor::synthetic_for_tests()));
    let pipeline = HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        predictor,
        pcfg,
    );

    let cfg = FleetConfig {
        admission_limit: admission,
        record_trace: true,
        ..Default::default()
    };
    let tenants = || -> Vec<TenantPool> {
        (0..n_tenants).map(|i| TenantPool::new(&format!("tenant-{i}"), tenant_cap)).collect()
    };
    let process = ArrivalProcess::Poisson { rate };

    println!(
        "fleet_sim: {n} x {} queries, {n_tenants} tenants, poisson {rate} q/s, \
         {edge_workers} edge / {cloud_workers} cloud workers, seed {seed}\n",
        bench.display()
    );

    // Run the identical workload twice; the virtual path must be exactly
    // reproducible (seeded RNG, no wall-clock anywhere).
    let first = serve_fleet(&pipeline, &cfg, tenants(), bench, n, &process, seed);
    let second = serve_fleet(&pipeline, &cfg, tenants(), bench, n, &process, seed);

    println!("{}\n", first.render());
    for t in &first.tenants {
        println!(
            "  tenant {:<10} queries-decided {:>4}  offload {:>5.1}%  spend ${:.4} (cap {})",
            t.name,
            t.state.n_decided,
            t.state.offload_rate() * 100.0,
            t.state.k_used,
            if t.k_cap.is_finite() { format!("${:.4}", t.k_cap) } else { "unlimited".into() },
        );
    }

    if args.flag("trace") {
        println!("\n--- event trace (first 40 lines) ---");
        for line in first.trace.iter().take(40) {
            println!("{line}");
        }
    }

    let ta = first.trace_text();
    let tb = second.trace_text();
    anyhow::ensure!(
        ta == tb,
        "determinism violated: the two runs produced different event traces"
    );
    println!(
        "\ndeterminism verified: two runs produced identical {}-line event traces",
        first.trace.len()
    );
    Ok(())
}

//! Fleet simulation driver on the declarative Scenario API: a seeded
//! multi-tenant workload on shared edge/cloud pools, run **twice** to
//! prove end-to-end determinism (the two event traces must match
//! byte-for-byte).
//!
//! The scenario is `scenario::presets::fleet_sim` — the same spec shipped
//! as `scenarios/fleet_sim.json`; pass `--spec-out <file>` to write the
//! exact spec this invocation ran, ready for
//! `hybridflow run --scenario <file>`.
//!
//! ```sh
//! cargo run --release --example fleet_sim -- \
//!     [--benchmark gpqa] [--n 60] [--rate 0.5] [--tenants 3] \
//!     [--edge-workers 8] [--cloud-workers 16] [--admission 64] \
//!     [--tenant-cap 0.02] [--seed 11] [--trace] [--spec-out fleet.json]
//! ```

use hybridflow::router::{MirrorPredictor, UtilityPredictor};
use hybridflow::scenario::presets::{self, FleetSimKnobs};
use hybridflow::util::cli::Args;
use hybridflow::workload::Benchmark;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let bench = Benchmark::parse(args.get_or("benchmark", "gpqa"))
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark"))?;
    let n = args.get_usize_or("n", 60)?;
    let rate = args.get_f64_or("rate", 0.5)?;
    let n_tenants = args.get_usize_or("tenants", 3)?.max(1);
    let edge_workers = args.get_usize_or("edge-workers", 8)?;
    let cloud_workers = args.get_usize_or("cloud-workers", 16)?;
    let admission = args.get_usize_or("admission", 64)?;
    let tenant_cap = args.get_f64("tenant-cap")?;
    let seed = args.get_u64_or("seed", 11)?;

    let knobs = FleetSimKnobs {
        n_tenants,
        edge_workers,
        cloud_workers,
        admission_limit: admission,
        tenant_cap: tenant_cap.filter(|c| c.is_finite()),
        record_trace: true,
    };
    let spec = presets::fleet_sim(bench, n, rate, seed, &knobs);
    if let Some(path) = args.get("spec-out") {
        std::fs::write(path, spec.render())?;
        println!("scenario spec written to {path}");
    }

    let artifacts = hybridflow::config::default_artifacts_dir();
    let predictor: Arc<dyn UtilityPredictor> =
        match MirrorPredictor::from_meta_file(&artifacts.join("router_meta.json")) {
            Ok(p) => Arc::new(p),
            Err(_) => Arc::new(MirrorPredictor::synthetic_for_tests()),
        };
    let session = spec.build(predictor)?;

    println!(
        "fleet_sim: {n} x {} queries, {n_tenants} tenants, poisson {rate} q/s, \
         {edge_workers} edge / {cloud_workers} cloud workers, seed {seed}\n",
        bench.display()
    );

    // Run the identical scenario twice; the virtual path must be exactly
    // reproducible (seeded RNG, cold tenant pools per run, no wall-clock
    // anywhere).
    let first = session.run();
    let second = session.run();

    println!("{}\n", first.render());
    for t in &first.tenants {
        println!(
            "  tenant {:<10} queries-decided {:>4}  offload {:>5.1}%  spend ${:.4} (cap {})",
            t.name,
            t.state.n_decided,
            t.state.offload_rate() * 100.0,
            t.state.k_used,
            if t.k_cap.is_finite() { format!("${:.4}", t.k_cap) } else { "unlimited".into() },
        );
    }

    if args.flag("trace") {
        println!("\n--- event trace (first 40 lines) ---");
        for line in first.trace.iter().take(40) {
            println!("{line}");
        }
    }

    let ta = first.trace_text();
    let tb = second.trace_text();
    anyhow::ensure!(
        ta == tb,
        "determinism violated: the two runs produced different event traces"
    );
    println!(
        "\ndeterminism verified: two runs produced identical {}-line event traces",
        first.trace.len()
    );
    Ok(())
}

//! Quickstart: one query through the full HybridFlow pipeline, with every
//! stage printed — plan XML, repaired DAG, per-subtask routing decisions,
//! and the final metrics.
//!
//! ```sh
//! cargo run --release --example quickstart -- [--benchmark gpqa] [--seed 3] [--pjrt]
//! ```

use hybridflow::config::simparams::SimParams;
use hybridflow::dag::emit_plan;
use hybridflow::models::SimExecutor;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::planner::Planner;
use hybridflow::router::{MirrorPredictor, UtilityPredictor};
use hybridflow::runtime::RouterService;
use hybridflow::util::cli::Args;
use hybridflow::util::rng::Rng;
use hybridflow::workload::{generate_queries, Benchmark};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let bench = Benchmark::parse(args.get_or("benchmark", "gpqa"))
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark"))?;
    let seed = args.get_u64_or("seed", 3)?;
    let artifacts = hybridflow::config::default_artifacts_dir();

    // 1. Predictor: PJRT service (AOT artifact) or pure-rust mirror.
    let predictor: Arc<dyn UtilityPredictor> = if args.flag("pjrt") {
        let svc = RouterService::start(&artifacts)?;
        println!("== runtime: PJRT {} (artifacts: {}) ==\n", svc.platform(), artifacts.display());
        Arc::new(svc)
    } else {
        Arc::new(MirrorPredictor::from_meta_file(&artifacts.join("router_meta.json"))?)
    };

    // 2. Pick a query from the synthetic benchmark.
    let query = generate_queries(bench, 8, seed).pop().unwrap();
    println!(
        "query: benchmark={} domain={} latent difficulty={:.2} prompt tokens={:.0}\n",
        bench.display(),
        query.domain_name(),
        query.difficulty,
        query.query_tokens
    );

    // 3. Planner: XML plan -> validate/repair -> executable DAG.
    let planner = SyntheticPlanner::paper_main();
    let mut rng = Rng::new(seed);
    let text = planner.plan_text(&query, &mut rng);
    println!("-- planner output ({:.2}s on-device) --\n{}\n", text.planning_latency, text.xml);
    let mut rng = Rng::new(seed);
    let plan = planner.plan(&query, 7, &mut rng);
    println!("-- executable DAG ({:?}) --\n{}\n", plan.outcome, emit_plan(&plan.dag));
    println!(
        "nodes={}  critical path={}  R_comp={:.2} (Eq. 28)\n",
        plan.dag.len(),
        plan.dag.critical_path_len().unwrap(),
        plan.dag.compression_ratio().unwrap()
    );

    // 4. Route + schedule + execute.
    let sp = SimParams::default();
    let pipeline = HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        planner,
        predictor,
        PipelineConfig::paper_default(&sp),
    );
    let mut rng = Rng::new(seed);
    let (exec, _) = pipeline.run_query_traced(&query, &mut rng);

    println!("-- routing & execution trace --");
    let mut events = exec.events.clone();
    events.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
    for e in &events {
        println!(
            "  node {:>2} pos {}  u_hat={:.3} tau={:.3} -> {:<5}  t=[{:>6.2}s..{:>6.2}s]  api=${:.4}",
            e.node,
            e.position,
            e.u_hat,
            e.tau,
            if e.cloud { "CLOUD" } else { "edge" },
            e.start,
            e.finish,
            e.api_cost
        );
    }
    println!(
        "\nresult: {}  C_time={:.2}s  C_API=${:.4}  offload={:.0}%  C_used={:.3}",
        if exec.correct { "CORRECT" } else { "wrong" },
        exec.latency,
        exec.api_cost,
        exec.offload_rate * 100.0,
        exec.budget.c_used
    );
    Ok(())
}

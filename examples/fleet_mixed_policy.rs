//! Mixed-policy fleet driver on the declarative Scenario API: three
//! tenants run *different* routing policies in one fleet (per-tenant
//! overrides in the scenario topology), served twice — hedged speculative
//! dispatch off, then on — to show the sojourn tail dropping while
//! accuracy holds and cancelled speculative calls are refunded.
//!
//! The scenario itself is `scenario::presets::mixed_policy` (shipped as
//! `scenarios/fleet_mixed_policy.json`), the same spec the
//! `fleet_mixed_policy` experiment runs, so this driver and the
//! experiment table can never drift apart.
//!
//! ```sh
//! cargo run --release --example fleet_mixed_policy -- \
//!     [--benchmark gpqa] [--n 60] [--rate 0.6] \
//!     [--edge-workers 4] [--cloud-workers 16] \
//!     [--hedge-threshold 0.55] [--seed 11]
//! ```

use hybridflow::router::{MirrorPredictor, UtilityPredictor};
use hybridflow::scenario::presets::{self, MixedPolicyKnobs};
use hybridflow::scenario::Report;
use hybridflow::util::cli::Args;
use hybridflow::workload::Benchmark;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let bench = Benchmark::parse(args.get_or("benchmark", "gpqa"))
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark"))?;
    let n = args.get_usize_or("n", 60)?;
    let rate = args.get_f64_or("rate", 0.6)?;
    let edge_workers = args.get_usize_or("edge-workers", 4)?;
    let cloud_workers = args.get_usize_or("cloud-workers", 16)?;
    let hedge_threshold = args.get_f64_or("hedge-threshold", 0.55)?;
    let seed = args.get_u64_or("seed", 11)?;

    let artifacts = hybridflow::config::default_artifacts_dir();
    let predictor: Arc<dyn UtilityPredictor> =
        match MirrorPredictor::from_meta_file(&artifacts.join("router_meta.json")) {
            Ok(p) => Arc::new(p),
            Err(_) => Arc::new(MirrorPredictor::synthetic_for_tests()),
        };

    let run = |hedge: bool| -> Report {
        let knobs = MixedPolicyKnobs {
            edge_workers,
            cloud_workers,
            hedge,
            hedge_threshold,
            record_trace: true,
        };
        presets::mixed_policy(bench, n, rate, seed, &knobs)
            .build(Arc::clone(&predictor))
            .expect("preset spec is valid")
            .run()
    };

    println!(
        "fleet_mixed_policy: {n} x {} queries, poisson {rate} q/s, \
         {edge_workers} edge / {cloud_workers} cloud workers, seed {seed}\n",
        bench.display()
    );

    let acc = |r: &Report| {
        r.results.iter().filter(|q| q.exec.correct).count() as f64
            / r.results.len().max(1) as f64
            * 100.0
    };

    let mut reports = Vec::new();
    for hedge in [false, true] {
        let report = run(hedge);
        println!("--- hedge {} ---", if hedge { "ON" } else { "off" });
        println!("{}", report.render());
        println!("accuracy: {:.2}%", acc(&report));
        for t in &report.tenants {
            println!(
                "  tenant {:<12} decided {:>4}  offload {:>5.1}%  spend ${:.4}",
                t.name,
                t.state.n_decided,
                t.state.offload_rate() * 100.0,
                t.state.k_used,
            );
        }
        println!();
        reports.push(report);
    }

    // Determinism: a repeat of the hedged run must reproduce its trace.
    let again = run(true);
    anyhow::ensure!(
        again.trace_text() == reports[1].trace_text(),
        "determinism violated: hedged run is not reproducible"
    );

    println!(
        "sojourn p95: {:.2}s (off) -> {:.2}s (on)   accuracy: {:.2}% -> {:.2}%   \
         cancelled {} / refunded ${:.4}",
        reports[0].sojourn.p95,
        reports[1].sojourn.p95,
        acc(&reports[0]),
        acc(&reports[1]),
        reports[1].hedge_cancelled,
        reports[1].hedge_refund,
    );
    println!("determinism verified: hedged rerun produced an identical event trace");
    Ok(())
}

//! Budget adaptivity demo: sweep the per-query normalized budget `C_max`
//! and watch the dual-ascent router trade accuracy for cost, then inject a
//! *cloud latency shift* mid-run and show the LinUCB calibration head
//! (Sec. 3.3, Eqs. 13–14) recovering utility where the static router
//! overspends.
//!
//! ```sh
//! cargo run --release --example budget_sweep -- [--benchmark gpqa] [--n 150]
//! ```

use hybridflow::bench::Table;
use hybridflow::config::simparams::SimParams;
use hybridflow::models::SimExecutor;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::router::threshold::Threshold;
use hybridflow::router::{MirrorPredictor, RoutePolicy};
use hybridflow::util::cli::Args;
use hybridflow::util::rng::Rng;
use hybridflow::workload::{generate_queries, Benchmark};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let bench = Benchmark::parse(args.get_or("benchmark", "gpqa"))
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark"))?;
    let n = args.get_usize_or("n", 150)?;
    let artifacts = hybridflow::config::default_artifacts_dir();
    let predictor =
        Arc::new(MirrorPredictor::from_meta_file(&artifacts.join("router_meta.json"))?);

    // --- Part 1: C_max sweep -------------------------------------------
    let mut t = Table::new(
        "Budget sweep: dual-ascent router vs normalized budget C_max",
        &["C_max", "Offload (%)", "Acc (%)", "C_time (s)", "C_API ($)", "C_used (mean)"],
    );
    for &c_max in &[0.05, 0.1, 0.2, 0.35, 0.5, 0.75, 1.0, 2.0] {
        let sp = SimParams::default();
        let mut threshold = Threshold::dual(&sp);
        if let Threshold::DualAscent(d) = &mut threshold {
            d.c_max = c_max;
        }
        let mut cfg = PipelineConfig::paper_default(&sp);
        cfg.policy = RoutePolicy::Learned { threshold, calibrate: false };
        cfg.persist_router = true; // streaming shadow price across the query stream
        let pipeline = HybridFlowPipeline::with_predictor(
            SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            predictor.clone(),
            cfg,
        );
        let mut rng = Rng::new(42);
        let mut correct = 0usize;
        let (mut lat, mut api, mut off, mut cu) = (0.0, 0.0, 0.0, 0.0);
        let queries = generate_queries(bench, n, 42);
        for q in &queries {
            let (exec, _) = pipeline.run_query_traced(q, &mut rng);
            correct += usize::from(exec.correct);
            lat += exec.latency;
            api += exec.api_cost;
            off += exec.offload_rate;
            cu += exec.budget.c_used;
        }
        let nf = n as f64;
        t.row(vec![
            format!("{c_max:.2}"),
            format!("{:.1}", off / nf * 100.0),
            format!("{:.2}", correct as f64 / nf * 100.0),
            format!("{:.2}", lat / nf),
            format!("{:.4}", api / nf),
            format!("{:.3}", cu / nf),
        ]);
    }
    t.print();

    // --- Part 2: cloud-latency shift + bandit calibration ----------------
    println!("\n== system shift: cloud RTT x6 mid-deployment ==");
    let make_shifted = || {
        let mut ex = SimExecutor::paper_pair();
        ex.cloud.params.serving.rtt_mean *= 6.0;
        ex
    };

    let mut t = Table::new(
        "Calibration under shift (same queries, shifted cloud)",
        &["Router", "Offload (%)", "Acc (%)", "C_time (s)", "C_API ($)"],
    );
    for (label, calibrate) in [("static utility (offline u_hat)", false), ("LinUCB-calibrated", true)] {
        let sp = SimParams::default();
        let mut cfg = PipelineConfig::paper_default(&sp);
        cfg.policy = RoutePolicy::Learned { threshold: Threshold::dual(&sp), calibrate };
        cfg.persist_router = true; // the bandit head must learn across queries
        let pipeline = HybridFlowPipeline::with_predictor(
            make_shifted(),
            SyntheticPlanner::paper_main(),
            predictor.clone(),
            cfg,
        );
        let mut rng = Rng::new(7);
        let queries = generate_queries(bench, n, 7);
        let mut correct = 0usize;
        let (mut lat, mut api, mut off) = (0.0, 0.0, 0.0);
        for q in &queries {
            let out = pipeline.run_query(q, &mut rng);
            correct += usize::from(out.correct);
            lat += out.latency;
            api += out.api_cost;
            off += out.offload_rate;
        }
        let nf = n as f64;
        t.row(vec![
            label.into(),
            format!("{:.1}", off / nf * 100.0),
            format!("{:.2}", correct as f64 / nf * 100.0),
            format!("{:.2}", lat / nf),
            format!("{:.4}", api / nf),
        ]);
    }
    t.print();
    println!("\n(The offline u_hat was profiled at the original RTT; after the shift each");
    println!("cloud call costs more latency than the router believes. The bandit head");
    println!("observes realized rewards and pulls the offload rate down.)");
    Ok(())
}

//! End-to-end serving driver — the system-level validation run recorded in
//! EXPERIMENTS.md: load the AOT artifacts through PJRT, serve a full
//! benchmark's queries concurrently through the coordinator, and report
//! accuracy, simulated C_time/C_API, and *real* coordinator throughput and
//! latency percentiles.
//!
//! All three layers compose here: L3 scheduling/routing in rust, the L2
//! router network executed via the PJRT runtime on every decision, and the
//! L1 Pallas kernel inside that artifact. With `--edge-compute`, simulated
//! edge executions additionally run the edge-LM block artifact per decode
//! chunk, putting real model FLOPs on the serving path.
//!
//! ```sh
//! cargo run --release --example serve_workload -- \
//!     [--benchmark gpqa] [--n 195] [--workers 8] [--mirror] [--edge-compute]
//! ```

use hybridflow::config::simparams::SimParams;
use hybridflow::models::SimExecutor;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::router::{MirrorPredictor, UtilityPredictor};
use hybridflow::runtime::RouterService;
use hybridflow::server::serve;
use hybridflow::util::cli::Args;
use hybridflow::workload::{generate_queries, Benchmark};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let bench = Benchmark::parse(args.get_or("benchmark", "gpqa"))
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark"))?;
    let n = args.get_usize_or("n", bench.params().n_queries)?;
    let workers = args.get_usize_or("workers", 8)?;
    let seed = args.get_u64_or("seed", 11)?;
    let artifacts = hybridflow::config::default_artifacts_dir();

    let mut executor = SimExecutor::paper_pair();
    let predictor: Arc<dyn UtilityPredictor> = if args.flag("mirror") {
        Arc::new(MirrorPredictor::from_meta_file(&artifacts.join("router_meta.json"))?)
    } else {
        let svc = Arc::new(RouterService::start(&artifacts)?);
        println!("PJRT runtime up: platform={} edge_lm={}", svc.platform(), svc.has_edge_lm());
        if args.flag("edge-compute") && svc.has_edge_lm() {
            let burn = Arc::clone(&svc);
            executor = executor.with_edge_compute(Arc::new(move |chunks| {
                let _ = burn.edge_burn(chunks);
            }));
            println!("edge-LM compute hook enabled (PJRT forward per decode chunk)");
        }
        svc
    };

    let sp = SimParams::default();
    let pipeline = Arc::new(HybridFlowPipeline::with_predictor(
        executor,
        SyntheticPlanner::paper_main(),
        predictor,
        PipelineConfig::paper_default(&sp),
    ));

    println!(
        "serving {} x {} on {} workers (predictor: {})\n",
        n,
        bench.display(),
        workers,
        pipeline.predictor.backend()
    );
    let queries = generate_queries(bench, n, seed);
    let report = serve(Arc::clone(&pipeline), queries, workers, seed);
    println!("{}", report.render());

    // Scaling sanity: single worker for the wall-clock comparison.
    if !args.flag("no-scaling") {
        let queries = generate_queries(bench, n.min(64), seed);
        let one = serve(Arc::clone(&pipeline), queries.clone(), 1, seed);
        let many = serve(pipeline, queries, workers, seed);
        println!(
            "\nscaling: 1 worker {:.1} q/s -> {} workers {:.1} q/s ({:.2}x)",
            one.throughput_qps,
            workers,
            many.throughput_qps,
            many.throughput_qps / one.throughput_qps
        );
    }
    Ok(())
}

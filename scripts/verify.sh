#!/usr/bin/env bash
# Tier-1 verification: release build + full test suite (which includes the
# fleet golden-trace and equivalence tests), plus an advisory rustfmt
# check. Run from the repo root: ./scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
# Includes rust/tests/fleet.rs: golden trace, fleet(N=1) == run_query
# equivalence, and the fleet property suite.
cargo test -q

echo "== example smoke runs =="
# Tiny-N runs of the fleet examples so regressions in runnable drivers
# (not just the library) fail fast. These are part of verification.
cargo run --release --example fleet_sim -- --n 6 --rate 2.0 --tenants 2
cargo run --release --example fleet_mixed_policy -- --n 6 --rate 1.0
cargo run --release --example fleet_cache -- --n 8 --rate 1.0 --distinct 3

echo "== cargo clippy --no-default-features (advisory) =="
# Lints are reported but do not fail verification (the seed predates
# clippy enforcement).
if cargo clippy --version >/dev/null 2>&1; then
    if ! cargo clippy --no-default-features; then
        echo "WARNING: cargo clippy reported issues (advisory only)"
    fi
else
    echo "clippy unavailable; skipping lint check"
fi

echo "== cargo fmt --check (advisory) =="
# The seed predates rustfmt enforcement, so formatting drift is reported
# but does not fail verification.
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --check; then
        echo "WARNING: cargo fmt --check reported drift (advisory only)"
    fi
else
    echo "rustfmt unavailable; skipping format check"
fi

echo "verify: OK"

#!/usr/bin/env bash
# Tier-1 verification: release build + full test suite (which includes the
# fleet golden-trace, kernel-equivalence, and scenario round-trip tests),
# example + scenario smoke runs, and an enforced rustfmt check. Run from
# the repo root: ./scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
# Includes rust/tests/fleet.rs: golden trace, fleet(N=1) == run_query
# equivalence, and the fleet property suite.
cargo test -q

echo "== bounded fuzz pass (invariant harness) =="
# Random-but-valid scenario specs through the kernel under the invariant
# checker, plus the adversarial boundary-value generator. The test-suite
# pass above already replayed the regression corpus and ran
# HYBRIDFLOW_FUZZ_CASES (default 64) randomized cases; this drives the
# CLI surface end to end.
cargo run --release -- fuzz --cases 32 --seed 0
cargo run --release -- fuzz --cases 32 --seed 0 --adversarial

echo "== example smoke runs =="
# Tiny-N runs of the fleet examples so regressions in runnable drivers
# (not just the library) fail fast. These are part of verification.
cargo run --release --example fleet_sim -- --n 6 --rate 2.0 --tenants 2
cargo run --release --example fleet_mixed_policy -- --n 6 --rate 1.0
cargo run --release --example fleet_cache -- --n 8 --rate 1.0 --distinct 3

echo "== scenario smoke run =="
# End-to-end: a shipped JSON scenario through the CLI (parse -> build ->
# kernel -> report). Part of verification.
cargo run --release -- run --scenario scenarios/fleet_sim.json

echo "== sweep scenario smoke run =="
# The shipped declarative sweep: parse -> grid -> parallel sessions ->
# tabulated report, with the JSON-out surface exercised end to end.
cargo run --release -- run --scenario scenarios/fleet_cache_sweep.json \
    --json /tmp/hybridflow_sweep_smoke.json
rm -f /tmp/hybridflow_sweep_smoke.json

echo "== sharded scenario smoke run =="
# The shipped sharded fleet at --shards 1 vs --shards 4: the override
# must change the report (per-shard pools/caps are real semantics), and
# re-running --shards 4 must reproduce it byte-for-byte (the sharded
# kernel's determinism contract; thread-count invariance is pinned by
# the test suite and the fuzz invariants above).
cargo run --release -- run --scenario scenarios/fleet_sharded.json \
    --shards 1 --json /tmp/hybridflow_shard1.json
cargo run --release -- run --scenario scenarios/fleet_sharded.json \
    --shards 4 --json /tmp/hybridflow_shard4.json
cargo run --release -- run --scenario scenarios/fleet_sharded.json \
    --shards 4 --json /tmp/hybridflow_shard4_rerun.json
if cmp -s /tmp/hybridflow_shard1.json /tmp/hybridflow_shard4.json; then
    echo "error: --shards override had no effect (1-shard and 4-shard reports identical)"
    exit 1
fi
diff /tmp/hybridflow_shard4.json /tmp/hybridflow_shard4_rerun.json
rm -f /tmp/hybridflow_shard1.json /tmp/hybridflow_shard4.json /tmp/hybridflow_shard4_rerun.json

echo "== observability smoke run =="
# The sharded fleet with the obs:: exports on: --trace-out / --metrics-out
# must write parseable artifacts (Chrome trace-event JSON + metrics
# JSONL), and re-running at a different worker-thread count must
# reproduce both byte-for-byte (the artifact determinism contract; the
# golden pins live in rust/tests/obs.rs).
cargo run --release -- run --scenario scenarios/fleet_sharded.json \
    --threads 1 --trace-out /tmp/hybridflow_obs_t1.json \
    --metrics-out /tmp/hybridflow_obs_t1.jsonl --metrics-interval 0.5
cargo run --release -- run --scenario scenarios/fleet_sharded.json \
    --threads 4 --trace-out /tmp/hybridflow_obs_t4.json \
    --metrics-out /tmp/hybridflow_obs_t4.jsonl --metrics-interval 0.5
diff /tmp/hybridflow_obs_t1.json /tmp/hybridflow_obs_t4.json
diff /tmp/hybridflow_obs_t1.jsonl /tmp/hybridflow_obs_t4.jsonl
if command -v python3 >/dev/null 2>&1; then
python3 - <<'EOF'
import json
with open("/tmp/hybridflow_obs_t1.json") as f:
    trace = json.load(f)
events = trace["traceEvents"]
assert events, "trace carries no events"
assert any(e["ph"] == "X" for e in events), "no complete events"
with open("/tmp/hybridflow_obs_t1.jsonl") as f:
    rows = [json.loads(line) for line in f if line.strip()]
assert rows, "metrics series is empty"
assert all("t" in r and "ready_depth" in r for r in rows), "metrics rows missing columns"
print(f"observability artifacts OK: {len(events)} trace events, {len(rows)} metrics rows")
EOF
else
    echo "python3 unavailable; structural validation is covered by rust/tests/obs.rs"
fi
rm -f /tmp/hybridflow_obs_t1.json /tmp/hybridflow_obs_t1.jsonl \
    /tmp/hybridflow_obs_t4.json /tmp/hybridflow_obs_t4.jsonl

echo "== fault-injection smoke run =="
# The shipped faulty fleet (transient failures, a cloud outage window,
# stragglers, timeout + retry + failover policies): rerunning must
# reproduce the report byte-for-byte, and so must forcing 4 worker
# threads (fault realizations are attempt-addressed, not
# thread-scheduled). --fault-seed reseeds the realization end to end.
cargo run --release -- run --scenario scenarios/fleet_faulty.json \
    --json /tmp/hybridflow_faulty_a.json
cargo run --release -- run --scenario scenarios/fleet_faulty.json \
    --json /tmp/hybridflow_faulty_b.json
cargo run --release -- run --scenario scenarios/fleet_faulty.json \
    --threads 4 --json /tmp/hybridflow_faulty_t4.json
diff /tmp/hybridflow_faulty_a.json /tmp/hybridflow_faulty_b.json
diff /tmp/hybridflow_faulty_a.json /tmp/hybridflow_faulty_t4.json
cargo run --release -- run --scenario scenarios/fleet_faulty.json --fault-seed 99
rm -f /tmp/hybridflow_faulty_a.json /tmp/hybridflow_faulty_b.json \
    /tmp/hybridflow_faulty_t4.json

echo "== determinism lint (enforced) =="
# The dependency-free source lint (analysis::lint): the committed tree
# must be clean, the --json report must be byte-identical across reruns,
# every seeded-bad fixture must draw a nonzero exit, and the
# allow-annotated/trap fixtures must pass.
cargo run --release -- lint
cargo run --release -- lint --json > /tmp/hybridflow_lint_a.json
cargo run --release -- lint --json > /tmp/hybridflow_lint_b.json
diff /tmp/hybridflow_lint_a.json /tmp/hybridflow_lint_b.json
rm -f /tmp/hybridflow_lint_a.json /tmp/hybridflow_lint_b.json
for bad in rust/tests/lint_fixtures/bad rust/tests/lint_fixtures/bad/sim; do
    if cargo run --release --quiet -- lint --src "$bad" >/dev/null 2>&1; then
        echo "error: lint passed the seeded-bad fixture tree $bad"
        exit 1
    fi
done
cargo run --release -- lint --src rust/tests/lint_fixtures/clean

echo "== scenario feasibility check (enforced) =="
# The static checker (analysis::scenario) over every shipped scenario
# (sweeps cell by cell); the overloaded corpus spec must draw a
# stability error (nonzero exit).
for s in scenarios/*.json; do
    cargo run --release -- check --scenario "$s"
done
if cargo run --release --quiet -- check \
    --scenario rust/tests/corpus/check_overloaded_pool.json >/dev/null 2>&1; then
    echo "error: feasibility checker passed the overloaded corpus spec"
    exit 1
fi

echo "== kernel perf bench (smoke, BENCH_SCALE=0.05) =="
# Emits BENCH_kernel.json (worker-pool + fleet-size scaling, indexed vs
# the retained linear-scan baseline) and self-validates that the artifact
# parses with util::json — a malformed emission exits non-zero.
BENCH_SCALE=0.05 cargo bench --bench kernel

echo "== cargo clippy --no-default-features (enforced) =="
# Enforced as of PR 9 against the pinned deny list in Cargo.toml's
# [lints.clippy] table (dbg_macro / todo / unimplemented /
# disallowed_types, the latter configured in clippy.toml to ban hash
# collections in default-feature code).
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --no-default-features
else
    echo "clippy unavailable; skipping lint check"
fi

echo "== cargo fmt --check (enforced) =="
# Formatting is enforced as of PR 4. If this fails, run `cargo fmt` (or
# `make fmt`) and commit the result.
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "rustfmt unavailable; skipping format check"
fi

echo "verify: OK"

#!/usr/bin/env bash
# Tier-1 verification: release build + full test suite (which includes the
# fleet golden-trace and equivalence tests), plus an advisory rustfmt
# check. Run from the repo root: ./scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
# Includes rust/tests/fleet.rs: golden trace, fleet(N=1) == run_query
# equivalence, and the fleet property suite.
cargo test -q

echo "== cargo fmt --check (advisory) =="
# The seed predates rustfmt enforcement, so formatting drift is reported
# but does not fail verification.
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --check; then
        echo "WARNING: cargo fmt --check reported drift (advisory only)"
    fi
else
    echo "rustfmt unavailable; skipping format check"
fi

echo "verify: OK"
